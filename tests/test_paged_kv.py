"""Paged KV-cache bookkeeping: allocator safety under interleaved
admit/append/free streams, the serving loop's block-conservation
invariant, and PagedKVCache table plumbing (DESIGN.md §2.7).

Host-side only (no jax compute) — runs in milliseconds, so many random
streams.  The hypothesis-driven twins live in tests/test_paged_kv_props.py
(skipped where hypothesis is absent); the device-side halves (paged
executors, engine parity) in tests/test_flash_decode.py and
tests/test_serving.py.
"""
import numpy as np
import pytest

from repro.serving.kv_cache import BlockAllocator, PagedKVCache
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import ContinuousBatcher, Request


# ---------------------------------------------------------------------------
# Allocator invariants under random interleaved op streams
# ---------------------------------------------------------------------------

def _check_no_double_assignment(a: BlockAllocator):
    assigned = [b for s in a.live_seqs for b in a.table(s)]
    assert len(assigned) == len(set(assigned)), "block double-assigned"
    free = set(a.free_ids())
    assert not (free & set(assigned)), "block both free and assigned"
    assert len(free) + len(assigned) == a.num_blocks, "blocks leaked"


@pytest.mark.parametrize("seed", range(20))
def test_random_interleaved_streams_deterministic(seed):
    """np.random twin of the hypothesis stream property (which needs the
    optional hypothesis dep): interleaved admit/append/free never
    double-assigns a block, conservation holds after every op, and
    draining restores the whole pool."""
    rng = np.random.default_rng(seed)
    num_blocks = int(rng.integers(2, 25))
    block = int(rng.choice([16, 128]))
    a = BlockAllocator(num_blocks, block)
    live: dict[int, int] = {}
    next_seq = 0
    for _ in range(int(rng.integers(1, 50))):
        op = rng.choice(["admit", "append", "free"] if live else ["admit"])
        if op == "admit":
            prompt = int(rng.integers(1, num_blocks * block + 1))
            max_new = int(rng.integers(0, 2 * block + 1))
            if a.can_admit(prompt + max_new):
                a.admit(next_seq, prompt, max_new)
                live[next_seq] = max(0, max_new - 1)
            else:
                with pytest.raises(MemoryError):
                    a.admit(next_seq, prompt, max_new)
            next_seq += 1
        elif op == "append":
            sid = int(rng.choice(sorted(live)))
            if live[sid] > 0:
                a.append_token(sid)
                live[sid] -= 1
        else:
            sid = int(rng.choice(sorted(live)))
            a.free(sid)
            del live[sid]
        _check_no_double_assignment(a)
        assert a.conserves()
        assert a.available_blocks >= 0
    for sid in list(live):
        a.free(sid)
    assert a.free_blocks == a.num_blocks
    assert a.allocated_blocks == 0 and a.conserves()


@pytest.mark.parametrize("seed", range(20))
@pytest.mark.parametrize("host_blocks", [None, 6])
def test_random_swap_interleavings_deterministic(seed, host_blocks):
    """np.random twin of the two-tier hypothesis swap property:
    interleaved admit/append/swap_out/swap_in/free across device + host
    tiers keeps both conservations, never dual-accounts a sequence, and
    draining empties both tiers (DESIGN.md §2.10)."""
    rng = np.random.default_rng(seed)
    num_blocks = int(rng.integers(2, 17))
    block = int(rng.choice([16, 128]))
    a = BlockAllocator(num_blocks, block, host_blocks=host_blocks)
    live: dict[int, int] = {}
    swapped: dict[int, int] = {}
    next_seq = 0
    for _ in range(int(rng.integers(1, 60))):
        ops = ["admit"]
        if live:
            ops += ["append", "free", "swap_out"]
        if swapped:
            ops += ["swap_in", "free_swapped"]
        op = rng.choice(ops)
        if op == "admit":
            prompt = int(rng.integers(1, num_blocks * block + 1))
            max_new = int(rng.integers(0, 2 * block + 1))
            if a.can_admit(prompt + max_new):
                a.admit(next_seq, prompt, max_new)
                live[next_seq] = max(0, max_new - 1)
            next_seq += 1
        elif op == "append":
            sid = int(rng.choice(sorted(live)))
            if live[sid] > 0:
                a.append_token(sid)
                live[sid] -= 1
        elif op == "swap_out":
            sid = int(rng.choice(sorted(live)))
            if a.can_swap_out(sid):
                resident = a.seq_tokens(sid)
                assert a.swap_out(sid) == a.blocks_needed(resident)
                assert a.host_tokens(sid) == resident
                swapped[sid] = live.pop(sid)
            else:
                assert host_blocks is not None
                with pytest.raises(MemoryError):
                    a.swap_out(sid)
        elif op == "swap_in":
            sid = int(rng.choice(sorted(swapped)))
            max_new = swapped[sid] + 1
            if a.can_swap_in(sid, max_new):
                resident = a.host_tokens(sid)
                ids = a.swap_in(sid, max_new)
                assert len(ids) == a.blocks_needed(resident)
                assert a.seq_tokens(sid) == resident
                live[sid] = swapped.pop(sid)
        elif op == "free_swapped":
            sid = int(rng.choice(sorted(swapped)))
            a.free(sid)
            del swapped[sid]
        else:
            sid = int(rng.choice(sorted(live)))
            a.free(sid)
            del live[sid]
        _check_no_double_assignment(a)
        assert not (set(a.live_seqs) & set(a.swapped_seqs))
        assert a.conserves()
        assert a.available_blocks >= 0
    # swapped-in sequences must still be able to decode to their budget
    for sid in list(live):
        while live[sid] > 0:
            a.append_token(sid)
            live[sid] -= 1
        a.free(sid)
    for sid in list(swapped):
        a.free(sid)
    assert a.free_blocks == a.num_blocks
    assert a.allocated_blocks == 0 and a.host_allocated_blocks == 0
    assert a.conserves()


def test_swap_roundtrip_accounting_exact():
    """One explicit round trip: swap_out releases exactly the mapped
    blocks AND the unmapped reservation headroom; swap_in re-reserves the
    worst case for the remaining tokens with fresh ids."""
    a = BlockAllocator(num_blocks=8, block=4, host_blocks=4)
    first = a.admit(1, 10, max_new_tokens=6)   # 3 mapped, 4 reserved
    assert len(first) == 3 and a.reserved_blocks(1) == 4
    assert a.available_blocks == 4
    released = a.swap_out(1)
    assert released == 3 and a.host_tokens(1) == 10
    assert a.available_blocks == 8             # reservation fully returned
    assert a.host_free_blocks == 1
    with pytest.raises(ValueError):
        a.swap_out(1)                          # already on the host tier
    ids = a.swap_in(1, max_new_tokens=6)
    assert len(ids) == 3 and a.seq_tokens(1) == 10
    assert a.reserved_blocks(1) == 4 and a.host_allocated_blocks == 0
    for _ in range(6):
        a.append_token(1)                      # the re-reservation holds
    assert a.seq_tokens(1) == 16
    a.free(1)
    assert a.free_blocks == 8 and a.conserves()


def test_host_capacity_refuses_swap_out():
    a = BlockAllocator(num_blocks=8, block=4, host_blocks=2)
    a.admit(1, 12)                             # 3 blocks > host capacity 2
    a.admit(2, 8)                              # 2 blocks == host capacity
    assert not a.can_swap_out(1)
    with pytest.raises(MemoryError):
        a.swap_out(1)
    assert a.can_swap_out(2)
    a.swap_out(2)
    assert not a.can_swap_out(1)               # tier now full
    a.free(1)
    a.free(2)                                  # free() clears the host tier
    assert a.host_allocated_blocks == 0 and a.free_blocks == 8


def test_freed_blocks_are_reused():
    """Blocks released by a completed sequence physically serve later
    sequences (the paged capacity story: one pool, many tenants)."""
    a = BlockAllocator(num_blocks=4, block=128)
    first = set(a.admit(1, 512))
    assert len(first) == 4
    a.free(1)
    second = set(a.admit(2, 512))
    assert second == first           # the very same physical blocks
    a.free(2)
    assert a.free_blocks == 4


def test_append_past_reservation_raises():
    a = BlockAllocator(num_blocks=8, block=4)
    a.admit(1, 3, max_new_tokens=1)  # reserved exactly 1 block
    a.append_token(1)                # token 4 still fits block 1
    with pytest.raises(MemoryError):
        a.append_token(1)            # token 5 needs an unreserved block
    assert a.conserves()             # the refused append left no trace


# ---------------------------------------------------------------------------
# Serving-loop conservation: allocated == sum(ceil(len/block)) every tick
# ---------------------------------------------------------------------------

class _FakeSteps:
    """Minimal closures for a host-only batcher drive."""

    def __init__(self, rng):
        self.rng = rng

    def prefill(self, toks, slot, q_offset, is_final, prompt_len):
        return int(self.rng.integers(0, 50)) if is_final else None

    def decode(self, slots, toks, pos):
        return self.rng.integers(0, 50, size=len(slots)).astype(np.int32)


def _conservation_holds(b: ContinuousBatcher) -> bool:
    a = b.alloc
    if not a.conserves():
        return False
    # cross-check allocator accounting against scheduler state: an active
    # sequence has written prompt + generated - 1 tokens (the newest
    # sampled token is in flight, not yet in the cache); a mid-prefill
    # sequence claimed its whole prompt at admission.
    for rid, req in b.active.items():
        if a.seq_tokens(rid) != len(req.prompt) + len(req.generated) - 1:
            return False
    if b.prefilling is not None:
        if a.seq_tokens(b.prefilling.rid) != len(b.prefilling.prompt):
            return False
    return True


@pytest.mark.parametrize("token_budget", [None, 128, 256])
@pytest.mark.parametrize("seed", range(8))
def test_block_conservation_every_tick(seed, token_budget):
    rng = np.random.default_rng(seed)
    num_slots = int(rng.integers(1, 5))
    b = ContinuousBatcher(num_slots=num_slots,
                          num_blocks=num_slots * 4, max_seq_len=512,
                          block=128, token_budget=token_budget)
    eng = _FakeSteps(rng)
    for i in range(int(rng.integers(3, 12))):
        length = int(rng.integers(1, 450))
        b.submit(Request(rid=i, prompt=np.arange(length) % 256,
                         sampling=SamplingParams(
                             max_tokens=int(rng.integers(1, 8)))))
    ticks = 0
    while b.busy and ticks < 10_000:
        b.tick(eng.prefill, eng.decode)
        assert _conservation_holds(b), f"conservation broken at tick {ticks}"
        ticks += 1
    assert not b.busy
    assert b.alloc.free_blocks == b.alloc.num_blocks
    assert b.alloc.allocated_blocks == 0


def test_decode_growth_maps_blocks_at_boundaries():
    """A request whose generation crosses a block boundary gains exactly
    one block at the crossing tick — the accounting admission control now
    sees (the old loop never called append_token, so generated tokens were
    invisible to the allocator)."""
    b = ContinuousBatcher(num_slots=1, num_blocks=4, max_seq_len=512,
                          block=128, token_budget=256)
    rng = np.random.default_rng(0)
    eng = _FakeSteps(rng)
    # 127-token prompt: the first decode writes position 127 — the last
    # row of block 1; the second decode crosses into block 2
    b.submit(Request(rid=0, prompt=np.arange(127),
                     sampling=SamplingParams(max_tokens=6)))
    b.tick(eng.prefill, eng.decode)   # admit + prefill + first decode
    assert len(b.alloc.table(0)) == 1
    assert b.alloc.seq_tokens(0) == 128
    b.tick(eng.prefill, eng.decode)   # second decode: boundary crossing
    assert len(b.alloc.table(0)) == 2
    assert b.alloc.seq_tokens(0) == 129
    b.run(eng.prefill, eng.decode)
    assert b.alloc.free_blocks == 4


# ---------------------------------------------------------------------------
# PagedKVCache plumbing
# ---------------------------------------------------------------------------

def _mk_pool(total_blocks):
    # stand-in device pool [L=1, 2, N, Hkv=1, block=4, Dh=2]
    return np.zeros((1, 2, total_blocks, 1, 4, 2), np.float32)


def test_paged_cache_trash_block_and_tables():
    kv = PagedKVCache(_mk_pool, num_blocks=6, block=4, table_width=3)
    assert kv.pool.shape[2] == 7          # +1 physical trash block
    assert kv.trash_block == 6            # ...outside the allocator's ids
    kv.alloc.admit(0, 9)                  # 3 blocks
    row = kv.table_row(0)
    assert row.shape == (3,) and (row >= 0).all()
    assert kv.trash_block not in set(row.tolist())
    kv.alloc.admit(1, 4)
    row1 = kv.table_row(1)
    assert row1[0] >= 0 and (row1[1:] == -1).all()
    assert not set(row.tolist()) & {int(row1[0])}
    kv.alloc.free(0)
    kv.alloc.free(1)
    assert kv.alloc.free_blocks == 6




# ---------------------------------------------------------------------------
# Stripe-owned pools (DESIGN.md §2.11)
# ---------------------------------------------------------------------------

def test_stripe_validation():
    with pytest.raises(ValueError):
        BlockAllocator(10, 4, stripes=3)     # 10 % 3 != 0
    with pytest.raises(ValueError):
        BlockAllocator(8, 4, stripes=0)
    a = BlockAllocator(12, 4, stripes=3)
    assert a.stripe_size == 4
    assert [a.stripe_of(b) for b in (0, 3, 4, 11)] == [0, 0, 1, 2]
    assert a.free_blocks_per_stripe() == [4, 4, 4]


def test_stripe_growth_routes_to_most_free():
    """_grow picks the most-free stripe per block (ties -> lowest index),
    so one sequence's blocks SPREAD across stripes — the §2.11 layout."""
    a = BlockAllocator(12, 4, stripes=3)
    a.admit(0, 6 * 4)                        # 6 blocks over 3 stripes
    assert a.stripe_counts(0) == [2, 2, 2]
    assert a.conserves()


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("stripes", [2, 3])
def test_striped_random_streams_conserve(seed, stripes):
    """The §2.7 stream property under striping: interleaved
    admit/append/free/swap keeps PER-STRIPE conservation (free + mapped
    == stripe_size, ids never leak across stripes)."""
    rng = np.random.default_rng(seed)
    num_blocks = stripes * int(rng.integers(2, 9))
    block = 16
    a = BlockAllocator(num_blocks, block, host_blocks=None, stripes=stripes)
    live: dict[int, int] = {}
    swapped: set[int] = set()
    next_seq = 0
    for _ in range(int(rng.integers(10, 60))):
        ops = ["admit"] + (["append", "free", "swap_out"] if live else []) \
            + (["swap_in"] if swapped else [])
        op = rng.choice(ops)
        if op == "admit":
            prompt = int(rng.integers(1, num_blocks * block + 1))
            max_new = int(rng.integers(0, 2 * block + 1))
            if a.can_admit(prompt + max_new):
                a.admit(next_seq, prompt, max_new)
                live[next_seq] = max(0, max_new - 1)
                next_seq += 1
        elif op == "append":
            sid = int(rng.choice(sorted(live)))
            if live[sid] > 0:
                a.append_token(sid)
                live[sid] -= 1
        elif op == "free":
            sid = int(rng.choice(sorted(live)))
            a.free(sid)
            del live[sid]
        elif op == "swap_out":
            sid = int(rng.choice(sorted(live)))
            if a.can_swap_out(sid):
                a.swap_out(sid)
                swapped.add(sid)
                del live[sid]
        else:
            sid = int(rng.choice(sorted(swapped)))
            if a.can_swap_in(sid):
                ids = a.swap_in(sid)
                # fresh ids all owned by their id-range stripes
                assert all(0 <= a.stripe_of(b) < stripes for b in ids)
                swapped.remove(sid)
                live[sid] = 0
        assert a.conserves()
        per = a.free_blocks_per_stripe()
        assert sum(per) == a.free_blocks
        assert all(0 <= f <= a.stripe_size for f in per)
    for sid in list(live):
        a.free(sid)
    for sid in list(swapped):
        a.swap_in(sid)
        a.free(sid)
    assert a.free_blocks == num_blocks and a.conserves()
    assert a.free_blocks_per_stripe() == [a.stripe_size] * stripes


def test_paged_cache_striped_pool():
    kv = PagedKVCache(_mk_pool, num_blocks=6, block=4, table_width=3,
                      stripes=2)
    assert kv.stripes == 2 and kv.stripe_size == 3
    assert kv.trash_block == 6               # trash sits OUTSIDE all stripes
    kv.alloc.admit(0, 9)                     # 3 blocks -> spread [2, 1]
    assert sorted(kv.alloc.stripe_counts(0)) == [1, 2]
    assert kv.alloc.conserves()


# ---------------------------------------------------------------------------
# admission partial-failure rollback (§2.13 satellite)
# ---------------------------------------------------------------------------
def test_admit_partial_failure_rolls_back_cleanly():
    """Regression: an admit that fails after mapping SOME prompt blocks
    must unwind them — before the rollback, the reservation and the
    already-popped free-list blocks leaked, so the pool shrank a little on
    every failed admission until nothing could admit."""
    from repro.serving.faults import FaultInjector, FaultPlan, FaultSpec
    from repro.serving.faults import InjectedAllocError

    a = BlockAllocator(8, 64)
    a.injector = FaultInjector(FaultPlan(specs=(
        FaultSpec(seam="admission_alloc", rid=1),)))
    a.admit(0, 100)                          # untouched bystander
    before = (a.free_blocks, a.allocated_blocks, sorted(a.free_ids()))
    with pytest.raises(MemoryError) as ei:   # InjectedAllocError IS one
        a.admit(1, 200, max_new_tokens=128)
    assert isinstance(ei.value, InjectedAllocError)
    # full unwind: no table, no length, no reservation, same free list
    assert 1 not in a.live_seqs
    assert a.table(1) == [] and a.seq_tokens(1) == 0
    assert a.reserved_blocks(1) == 0
    assert (a.free_blocks, a.allocated_blocks,
            sorted(a.free_ids())) == before
    assert a.conserves() and not a.audit(strict=False)
    # the spec is spent: the SAME admit now lands fully
    ids = a.admit(1, 200, max_new_tokens=128)
    assert len(ids) == a.blocks_needed(200)
    a.free(0)
    a.free(1)
    assert a.free_blocks == a.num_blocks


def test_admit_genuine_exhaustion_mid_map_rolls_back():
    """The same unwind without an injector: a reservation that fits but a
    free list that runs dry mid-map (possible transiently with stripes)
    must leave no trace either."""
    a = BlockAllocator(4, 64)
    a.admit(0, 64)                           # 1 block mapped, 3 free
    # reservation check passes (3 needed <= 3 available) but we drain the
    # free list underneath the mapping loop to force the mid-map failure
    stolen, a._free[0] = a._free[0][1:], a._free[0][:1]
    with pytest.raises(MemoryError):
        a.admit(1, 192)
    assert 1 not in a.live_seqs and a.reserved_blocks(1) == 0
    a._free[0] += stolen                     # put the stolen blocks back
    assert a.conserves()
    assert a.admit(1, 192) and a.conserves()
