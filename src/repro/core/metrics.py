"""Metrics used across benchmarks and tests (paper's evaluation quantities).

- imbalance ratio I (paper Eq. 2) over any load vector,
- attention-output fidelity (cosine / relative error vs full attention),
- recovery statistics,
- latency model helpers: convert work-list / HLO counts into roofline times
  for the target TPU v5e (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# TPU v5e hardware constants (per chip) — the roofline targets.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # bytes/s
ICI_BW_PER_LINK = 50e9        # bytes/s per link (~)


def imbalance_ratio(loads) -> float:
    """Paper Eq. (2): I = max_d L_d / mean_d L_d."""
    loads = np.asarray(loads, dtype=np.float64)
    mean = loads.mean()
    return float(loads.max() / mean) if mean > 0 else 1.0


def attention_fidelity(out_sparse: np.ndarray, out_full: np.ndarray) -> dict:
    """Output-level quality of a sparse attention vs the full oracle."""
    a = np.asarray(out_sparse, np.float64).ravel()
    b = np.asarray(out_full, np.float64).ravel()
    denom = max(float(np.linalg.norm(b)), 1e-12)
    rel = float(np.linalg.norm(a - b)) / denom
    cos = float(np.dot(a, b) / max(np.linalg.norm(a) * np.linalg.norm(b), 1e-12))
    return {"rel_err": rel, "cosine": cos, "max_abs": float(np.abs(a - b).max())}


@dataclasses.dataclass
class RooflineTerms:
    """Three-term roofline estimate, in seconds (per §ROOFLINE)."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline lower bound on step time = max of the three terms
        (assuming perfect overlap between the pipes)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "bound_s": self.bound_s,
        }


def roofline(
    flops: float, hbm_bytes: float, collective_bytes: float,
    num_chips: int, *, ici_links: int = 4,
) -> RooflineTerms:
    """Roofline terms for a step executed on ``num_chips`` TPU v5e chips.

    ``flops`` / ``hbm_bytes`` are TOTALS over the job (cost_analysis of the
    whole step); ``collective_bytes`` is the summed operand bytes of
    collective ops in the lowered HLO.  ``ici_links``: per-chip ICI links
    usable concurrently (v5e 2D torus: 4).
    """
    return RooflineTerms(
        compute_s=flops / (num_chips * PEAK_FLOPS_BF16),
        memory_s=hbm_bytes / (num_chips * HBM_BW),
        collective_s=collective_bytes / (num_chips * ici_links * ICI_BW_PER_LINK),
    )


def model_flops_train(n_params: int, n_tokens: int) -> float:
    """The 6*N*D rule for a train step (fwd+bwd)."""
    return 6.0 * n_params * n_tokens


def model_flops_infer(n_params: int, n_tokens: int) -> float:
    """2*N*D for a forward pass."""
    return 2.0 * n_params * n_tokens


def mfu(model_flops: float, step_time_s: float, num_chips: int) -> float:
    return model_flops / (step_time_s * num_chips * PEAK_FLOPS_BF16)


def slo_attainment(ttfts, itls, *, ttft_target_s: float,
                   itl_target_s: float, num_submitted: int | None = None,
                   itl_quantile: float = 0.99) -> dict:
    """Per-class SLO attainment for the overload benchmark (DESIGN.md
    §2.10): a request ATTAINS its SLO when its TTFT meets the class
    target and its per-request p-``itl_quantile`` inter-token latency
    meets the ITL target.

    ``ttfts``: one TTFT per COMPLETED request; ``itls``: the matching
    per-request ITL sample lists (empty list = single-token request, ITL
    vacuously met).  ``num_submitted`` scores attainment against every
    submitted request (rejected/unfinished count as missed) — the honest
    overload denominator; None scores completed requests only.
    """
    ttfts = list(ttfts)
    itls = list(itls)
    assert len(ttfts) == len(itls), "one ITL list per completed request"
    ok = 0
    for ttft, samples in zip(ttfts, itls):
        if ttft is None or ttft > ttft_target_s:
            continue
        if samples and float(np.quantile(
                np.asarray(samples, np.float64),
                itl_quantile)) > itl_target_s:
            continue
        ok += 1
    denom = num_submitted if num_submitted is not None else len(ttfts)
    return {
        "attained": ok,
        "completed": len(ttfts),
        "denominator": denom,
        "attainment": ok / denom if denom else 1.0,
    }
