"""Property-based chaos testing of the self-healing scheduler
(DESIGN.md §2.13).

Hypothesis draws a seed; from it we derive BOTH a random request stream
(class-tagged, staggered arrivals, over-length outliers) and a random
seeded fault schedule (admission exhaustion via the allocator's injector
seam, swap-transfer failures raised from the engine-side hooks, sentinel
quarantines of random active slots).  The faults interleave with
admit / append / preempt / swap / resume exactly as they would in the
real engine, and EVERY tick must uphold:

- request conservation: ``completed + rejected + failed`` equals the
  number of requests handed back so far, and equals ``submitted`` after
  drain — a fault may kill a request, never lose one;
- two-tier block conservation: the allocator's device + host accounting
  balances (``conserves()``) at every tick boundary, not just at drain.

Pure host-side (FakeEngine, no jax) so Hypothesis can afford many
examples; the real-engine counterparts (device scrubbing, replan
interleaving, bitwise victim isolation) live in tests/test_faults.py.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container without hypothesis: seeded sweep
    HAVE_HYPOTHESIS = False

from repro.serving.faults import (  # noqa: E402
    FaultInjector,
    FaultPlan,
    TransferError,
)
from repro.serving.sampler import SamplingParams  # noqa: E402
from repro.serving.scheduler import (  # noqa: E402
    DEFAULT_CLASSES,
    ContinuousBatcher,
    Request,
)


class ChaoticFake:
    """Slot-accurate fake engine with fault hooks: swap transfers consult
    the injector (raising TransferError like the engine's exhausted retry
    gate), and a seeded sentinel randomly quarantines active slots."""

    def __init__(self, b: ContinuousBatcher, rng, injector,
                 sentinel_p: float):
        self.b = b
        self.rng = rng
        self.injector = injector
        self.sentinel_p = sentinel_p
        self.on_fail_calls: list[tuple] = []

    def prefill(self, toks, slot, q_offset, is_final, prompt_len):
        return int(self.rng.integers(0, 50)) if is_final else None

    def decode(self, slots, toks, pos):
        return self.rng.integers(0, 50, size=len(slots)).astype(np.int32)

    def swap_out(self, rid, slot, resident):
        spec = self.injector.fire("swap_out_transfer", rid=rid)
        if spec is not None:
            raise TransferError("swap_out_transfer", "injected", rid=rid)

    def swap_in(self, rid, slot, resident):
        spec = self.injector.fire("swap_in_transfer", rid=rid)
        if spec is not None:
            raise TransferError("swap_in_transfer", "injected", rid=rid)

    def sentinel(self):
        """Quarantine each active decode slot with probability
        ``sentinel_p`` (seeded — reruns reproduce)."""
        out = {}
        for slot in list(self.b._rid_of):
            if self.rng.random() < self.sentinel_p:
                out[slot] = "injected_sentinel"
        return out

    def on_fail(self, rid, slot):
        self.on_fail_calls.append((rid, slot))


def _chaos_stream(seed: int):
    rng = np.random.default_rng(seed)
    num_slots = int(rng.integers(1, 4))
    max_seq_len, block = 512, 128
    num_blocks = int(rng.integers(num_slots + 1, num_slots * 4 + 1))
    n = int(rng.integers(4, 16))
    plan = FaultPlan.random(
        seed, rate=float(rng.uniform(0.0, 0.15)), horizon=40,
        seams=("admission_alloc", "swap_out_transfer",
               "swap_in_transfer"), max_rid=n)
    injector = FaultInjector(plan)
    b = ContinuousBatcher(
        num_slots=num_slots, num_blocks=num_blocks,
        max_seq_len=max_seq_len, block=block,
        token_budget=[None, 128, 256][int(rng.integers(0, 3))],
        admission=["fifo", "slo"][int(rng.integers(0, 2))],
        preemption=True,
        host_blocks=[None, 0, 4][int(rng.integers(0, 3))])
    eng = ChaoticFake(b, rng, injector,
                      sentinel_p=float(rng.uniform(0.0, 0.06)))
    b.swap_out_fn = eng.swap_out
    b.swap_in_fn = eng.swap_in
    b.sentinel_fn = eng.sentinel
    b.on_fail_fn = eng.on_fail
    b.alloc.injector = injector      # admission_alloc seam inside _grow
    names = [c.name for c in DEFAULT_CLASSES]
    reqs = []
    for i in range(n):
        length = (int(rng.integers(max_seq_len, max_seq_len * 2))
                  if rng.random() < 1 / 8
                  else int(rng.integers(1, 400)))
        reqs.append(Request(
            rid=i, prompt=np.arange(length) % 256,
            sampling=SamplingParams(max_tokens=int(rng.integers(1, 8))),
            priority=names[int(rng.integers(0, len(names)))]))
    return b, eng, reqs


def _chaos_conservation_every_tick(seed):
    b, eng, reqs = _chaos_stream(seed)
    rng = np.random.default_rng(seed + 1)
    cut = int(rng.integers(0, len(reqs) + 1))
    for r in reqs[:cut]:
        b.submit(r)
    done: list[Request] = []
    ticks = 0
    submitted_rest = False
    while (b.busy or not submitted_rest) and ticks < 5_000:
        done.extend(b.tick(eng.prefill, eng.decode))
        ticks += 1
        if not submitted_rest and ticks >= int(rng.integers(1, 6)):
            for r in reqs[cut:]:
                b.submit(r)
            submitted_rest = True
        # per-tick invariants — not just at drain
        st_ = b.stats
        assert st_.completed + st_.rejected + st_.failed == len(done), \
            "a request left the system without being handed back"
        assert b.alloc.conserves(), \
            "two-tier block conservation broke mid-stream"
    assert not b.busy, "chaos stream failed to drain"

    # drain invariants
    st_ = b.stats
    assert sorted(r.rid for r in done) == sorted(r.rid for r in reqs)
    assert st_.completed + st_.rejected + st_.failed == len(reqs)
    assert b.alloc.conserves()
    assert b.alloc.free_blocks == b.alloc.num_blocks
    assert b.alloc.host_allocated_blocks == 0
    assert b.alloc.swapped_seqs == () and b._slot_of == {}
    # every quarantined victim carries a structured reason and got its
    # engine-side scrub callback exactly once
    failed = [r for r in done if r.failed]
    assert len(failed) == st_.failed
    for r in failed:
        assert r.done and r.fail_reason
        assert r.generated is not None     # partial output is kept
    assert len([c for c in eng.on_fail_calls]) >= len(failed)
    # per-class ledgers still partition the totals under chaos
    per = b.stats.per_class
    assert sum(c["submitted"] for c in per.values()) == len(reqs)
    for name, c in per.items():
        assert c["completed"] + c["rejected"] + c["failed"] == \
            c["submitted"], name


if HAVE_HYPOTHESIS:
    @pytest.mark.timeout(900, method="thread")
    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=100_000))
    def test_chaos_conservation_every_tick(seed):
        _chaos_conservation_every_tick(seed)
else:
    @pytest.mark.timeout(900, method="thread")
    @pytest.mark.parametrize("seed", range(40))
    def test_chaos_conservation_every_tick(seed):
        _chaos_conservation_every_tick(seed)


@pytest.mark.timeout(300)
def test_fault_plan_roundtrip_and_determinism():
    plan = FaultPlan.random(7, 0.1, horizon=30, max_rid=12)
    again = FaultPlan.random(7, 0.1, horizon=30, max_rid=12)
    assert plan.to_json() == again.to_json(), "seeded plans must replay"
    back = FaultPlan.from_json(plan.to_json())
    assert back.to_json() == plan.to_json()
    # two injectors over the same plan fire identically
    a, c = FaultInjector(plan), FaultInjector(back)
    fires_a = [a.fire("kv_corrupt", rid=i % 5) is not None
               for i in range(50)]
    fires_c = [c.fire("kv_corrupt", rid=i % 5) is not None
               for i in range(50)]
    assert fires_a == fires_c
