"""Budget allocation: paper's max-min greedy vs exact oracle + invariants."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.budget import (
    maxmin_allocation,
    topp_allocation,
    uniform_allocation,
    waterfill_allocation,
)
from repro.core.sparsity import synthetic_head_curves

SEQ = 8192
BLOCK = 128


def _prof(heads=16, seed=0):
    return synthetic_head_curves(1, heads, seed=seed)


class TestUniform:
    def test_equal_budgets(self):
        a = uniform_allocation(_prof(), layer=0, k=1024, seq_len=SEQ)
        assert (a.budgets == a.budgets[0]).all()
        assert a.budgets[0] == 1024

    def test_quantization_and_floor(self):
        a = uniform_allocation(_prof(), layer=0, k=100, seq_len=SEQ)
        assert (a.budgets == 128).all()  # floored to one block


class TestMaxMin:
    def test_conserves_total(self):
        total = 16 * 1024
        a = maxmin_allocation(_prof(), layer=0, total=total, seq_len=SEQ)
        assert abs(a.total - total) < BLOCK * 2

    def test_improves_min_recovery_over_uniform(self):
        total = 16 * 1024
        u = uniform_allocation(_prof(), layer=0, k=1024, seq_len=SEQ)
        m = maxmin_allocation(_prof(), layer=0, total=total, seq_len=SEQ)
        assert m.min_recovery >= u.min_recovery - 1e-9

    def test_respects_floor(self):
        a = maxmin_allocation(_prof(), layer=0, total=16 * 256, seq_len=SEQ)
        assert (a.budgets >= 128).all()

    def test_block_quantized(self):
        a = maxmin_allocation(_prof(), layer=0, total=16 * 1000, seq_len=SEQ)
        assert (a.budgets % BLOCK == 0).all()

    def test_warm_start_fixed_point(self):
        """Incremental replanning (DESIGN.md §2.9): warm-starting from the
        converged allocation on the SAME profile is a fixed point — zero
        (or near-zero) transfers, identical budgets."""
        total = 16 * 1024
        a = maxmin_allocation(_prof(), layer=0, total=total, seq_len=SEQ)
        b = maxmin_allocation(_prof(), layer=0, total=total, seq_len=SEQ,
                              init_budgets=a.budgets)
        np.testing.assert_array_equal(a.budgets, b.budgets)
        assert b.iterations <= 2

    def test_warm_start_converges_faster_under_mild_drift(self):
        """A mildly jittered profile re-solves from the previous budgets
        in (far) fewer transfers than from the uniform split, and reaches
        at least the same min recovery."""
        total = 16 * 1024
        prof0 = synthetic_head_curves(1, 16, seed=0)
        prof1 = synthetic_head_curves(1, 16, seed=1)  # jittered identities
        a = maxmin_allocation(prof0, layer=0, total=total, seq_len=SEQ)
        cold = maxmin_allocation(prof1, layer=0, total=total, seq_len=SEQ)
        warm = maxmin_allocation(prof1, layer=0, total=total, seq_len=SEQ,
                                 init_budgets=a.budgets)
        assert warm.iterations <= cold.iterations
        assert warm.min_recovery >= cold.min_recovery - 0.05
        assert abs(warm.total - total) < BLOCK * 2

    def test_warm_start_recenters_changed_total(self):
        """The warm start is re-centered first, so a replan can also grow
        or shrink the global budget."""
        a = maxmin_allocation(_prof(), layer=0, total=16 * 1024,
                              seq_len=SEQ)
        grown = maxmin_allocation(_prof(), layer=0, total=16 * 2048,
                                  seq_len=SEQ, init_budgets=a.budgets)
        assert abs(grown.total - 16 * 2048) < BLOCK * 2

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 100), heads=st.sampled_from([4, 8, 9, 16]),
           k=st.sampled_from([256, 512, 2048]))
    def test_greedy_near_waterfill_oracle(self, seed, heads, k):
        """Property: the paper's greedy reaches the exact max-min optimum to
        within (a little more than) one block quantum of recovery."""
        prof = synthetic_head_curves(1, heads, seed=seed)
        total = heads * k
        g = maxmin_allocation(prof, layer=0, total=total, seq_len=SEQ)
        w = waterfill_allocation(prof, layer=0, total=total, seq_len=SEQ)
        assert w.min_recovery >= g.min_recovery - 0.05
        assert g.min_recovery >= w.min_recovery - 0.05


class TestTopP:
    def test_budgets_hit_target_recovery(self):
        a = topp_allocation(_prof(), layer=0, p=0.9, seq_len=SEQ)
        # every non-saturated head reaches >= p recovery
        assert (a.recovery >= 0.9 - 0.02).all()

    def test_total_varies_with_p(self):
        lo = topp_allocation(_prof(), layer=0, p=0.5, seq_len=SEQ)
        hi = topp_allocation(_prof(), layer=0, p=0.95, seq_len=SEQ)
        assert hi.total > lo.total


class TestProfile:
    def test_recovery_curves_monotone(self):
        p = _prof()
        assert (np.diff(p.curves, axis=-1) >= -1e-12).all()

    def test_stability_across_seeds(self):
        """Paper Fig. 6: per-head budgets correlate strongly across
        calibration sets (different seeds = different datasets)."""
        a, b = _prof(seed=0), _prof(seed=5)
        assert a.stability_vs(b) > 0.95

    def test_heterogeneity_exists(self):
        p = _prof()
        assert p.heterogeneity(0, target=0.9) > 2.0  # paper Fig. 4

    def test_serialization_roundtrip(self, tmp_path):
        p = _prof()
        path = str(tmp_path / "prof.npz")
        p.save(path)
        from repro.core.sparsity import HeadSparsityProfile
        q = HeadSparsityProfile.load(path)
        np.testing.assert_allclose(p.curves, q.curves)
        np.testing.assert_allclose(p.grid, q.grid)


class TestRecoveryCurve:
    def test_uniform_attention(self):
        """Uniform weights: top-k fraction f recovers exactly f."""
        from repro.core.sparsity import recovery_curve
        n = 256
        w = np.tril(np.ones((n, n))) / np.arange(1, n + 1)[:, None]
        grid = np.array([0.0, 0.25, 0.5, 1.0])
        rec = recovery_curve(w, grid)
        assert rec[-1] == pytest.approx(1.0, abs=1e-9)
        assert rec[1] == pytest.approx(0.25, abs=0.05)

    def test_delta_attention(self):
        """All mass on one token: any nonzero budget recovers ~1."""
        from repro.core.sparsity import recovery_curve
        n = 128
        w = np.zeros((n, n))
        w[np.arange(n), 0] = 1.0
        rec = recovery_curve(w, np.array([0.01, 0.5, 1.0]))
        assert rec[0] == pytest.approx(1.0, abs=1e-9)
