"""Serving: engine fidelity, continuous batching, cache bookkeeping,
sampler."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.sparsity import synthetic_head_curves
from repro.models.transformer import TransformerConfig, init_params
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.kv_cache import BlockAllocator
from repro.serving.sampler import sample
from repro.serving.scheduler import ContinuousBatcher, Request

CFG = TransformerConfig(num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=2, d_ff=128, vocab_size=256,
                        layer_loop="unroll")


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def profile():
    return synthetic_head_curves(CFG.num_layers, CFG.num_heads)


class TestEngineFidelity:
    def test_sparse_full_budget_matches_dense(self, params, profile):
        """Budget = seq_len => S-HPLB sparse serving reproduces the dense
        engine's greedy outputs exactly (permutation is a no-op on the
        function; work-lists cover the full causal set)."""
        prompts = [np.random.default_rng(i).integers(0, 256, size=(40,))
                   for i in range(3)]
        dense = Engine(CFG, params,
                       EngineConfig(attention="dense", max_seq_len=256,
                                    num_slots=4))
        sparse = Engine(CFG, params,
                        EngineConfig(attention="sparse",
                                     budget_per_head=256,  # == max_seq_len
                                     max_seq_len=256, num_slots=4),
                        profile=profile)
        sp = SamplingParams(max_tokens=8)  # greedy
        da = dense.serve(prompts, sp)
        sa = sparse.serve(prompts, sp)
        for a, b in zip(da, sa):
            assert a.generated == b.generated

    def test_sparse_low_budget_still_generates(self, params, profile):
        eng = Engine(CFG, params,
                     EngineConfig(attention="sparse", budget_per_head=128,
                                  max_seq_len=256, num_slots=2),
                     profile=profile)
        done = eng.serve([np.arange(50) % 256], SamplingParams(max_tokens=5))
        assert len(done) == 1 and len(done[0].generated) == 5


class TestEngineHotPath:
    def test_prefill_bucketing_bounds_compiles(self, params, profile):
        """Distinct prompt lengths map onto pow2 chunk buckets: compile
        count is O(log chunk_tokens), not O(#lengths)."""
        eng = Engine(CFG, params,
                     EngineConfig(attention="sparse", budget_per_head=256,
                                  max_seq_len=256, num_slots=4),
                     profile=profile)
        prompts = [np.arange(n) % 256 for n in (10, 23, 40, 100, 129, 200)]
        done = eng.serve(prompts, SamplingParams(max_tokens=3))
        assert len(done) == len(prompts)
        # 6 lengths -> at most {128, 256} chunk buckets
        assert set(eng._prefill_chunk_jit) <= {128, 256}

    def test_bucketed_matches_exact_prefill(self, params, profile):
        """Padding a prompt up to its bucket changes nothing downstream."""
        mk = lambda mode: Engine(
            CFG, params,
            EngineConfig(attention="sparse", budget_per_head=256,
                         max_seq_len=256, num_slots=2,
                         prefill_buckets=mode, prefill_mode="monolithic"),
            profile=profile)
        prompts = [np.random.default_rng(3).integers(0, 256, size=(37,))]
        sp = SamplingParams(max_tokens=6)  # greedy
        a = mk("pow2").serve(prompts, sp)
        b = mk("exact").serve(prompts, sp)
        assert a[0].generated == b[0].generated

    @pytest.mark.parametrize("attn", ["sparse", "dense"])
    def test_chunked_matches_monolithic_serve(self, params, profile, attn):
        """Greedy generations are IDENTICAL between chunked and monolithic
        prefill — chunk work-lists are slices of the monolithic lists and
        the chunk executor accumulates the same tiles in the same order."""
        prompts = [np.random.default_rng(i).integers(0, 256, size=(n,))
                   for i, n in enumerate((40, 300, 130, 70))]
        sp = SamplingParams(max_tokens=8)  # greedy
        outs = {}
        for mode in ("monolithic", "chunked"):
            eng = Engine(
                CFG, params,
                EngineConfig(attention=attn, budget_per_head=512,
                             max_seq_len=512, num_slots=4,
                             prefill_mode=mode, prefill_chunk_tokens=128),
                profile=profile if attn == "sparse" else None)
            outs[mode] = [r.generated for r in eng.serve(prompts, sp)]
        assert outs["chunked"] == outs["monolithic"]

    @pytest.mark.parametrize("attn,max_seq,chunk,plen", [
        # final chunk's pow2 bucket exceeds the cache rows left after
        # q_offset (regression: the K/V write clamped and overwrote
        # earlier rows)
        ("dense", 896, 512, 880),
        ("sparse", 896, 512, 880),
        # chunk budget NOT a pow2 multiple of block (regression: the
        # bucket spanned more q-blocks than the work-list slice covered)
        ("sparse", 512, 192, 300),
    ])
    def test_chunked_matches_monolithic_odd_geometry(self, params, profile,
                                                     attn, max_seq, chunk,
                                                     plen):
        prompts = [np.random.default_rng(7).integers(0, 256, size=(plen,)),
                   np.random.default_rng(8).integers(0, 256, size=(70,))]
        sp = SamplingParams(max_tokens=8)  # greedy
        outs = {}
        for mode in ("monolithic", "chunked"):
            eng = Engine(
                CFG, params,
                EngineConfig(attention=attn, budget_per_head=max_seq,
                             max_seq_len=max_seq, num_slots=2,
                             prefill_mode=mode, prefill_chunk_tokens=chunk),
                profile=profile if attn == "sparse" else None)
            outs[mode] = [r.generated for r in eng.serve(prompts, sp)]
        assert outs["chunked"] == outs["monolithic"]

    def test_mixed_ticks_interleave_prefill_and_decode(self, params,
                                                       profile):
        """A long admission no longer stalls the decode batch: while the
        long prompt chunk-prefills, earlier requests keep decoding."""
        eng = Engine(CFG, params,
                     EngineConfig(attention="sparse", budget_per_head=512,
                                  max_seq_len=512, num_slots=4,
                                  prefill_chunk_tokens=128),
                     profile=profile)
        sp = SamplingParams(max_tokens=12)
        batcher = eng.make_batcher()
        pf, df = eng.step_fns(sp)
        batcher.submit(Request(rid=0, prompt=np.arange(30) % 256,
                               sampling=sp))
        batcher.tick(pf, df)          # rid 0 prefilled + first decode
        assert 0 in batcher.active
        batcher.submit(Request(rid=1, prompt=np.arange(400) % 256,
                               sampling=sp))
        n0 = len(batcher.active[0].generated)
        ticks_while_prefilling = 0
        while batcher.prefilling is not None or batcher.pending:
            batcher.tick(pf, df)
            ticks_while_prefilling += 1
        # the 400-token prompt needed multiple chunk ticks, and rid 0
        # decoded through every one of them
        assert ticks_while_prefilling >= 3
        assert len(batcher.active[0].generated) >= n0 + 3
        batcher.run(pf, df)
        assert batcher.stats.completed == 2

    def test_decode_selection_tracks_position(self, params, profile):
        """Block selection is recomputed as slots cross block boundaries
        instead of being frozen at max_seq_len."""
        eng = Engine(CFG, params,
                     EngineConfig(attention="sparse", budget_per_head=128,
                                  max_seq_len=512, num_slots=1),
                     profile=profile)
        eng.serve([np.arange(250) % 256], SamplingParams(max_tokens=12))
        # crossed the 256-token boundary mid-generation: ids for both block
        # counts were materialized (under epoch 0), at the capped width
        assert {(0, 2), (0, 3)} <= set(eng._decode_ids_by_nblocks)
        widths = {a.shape[-1] for a in eng._decode_ids_by_nblocks.values()}
        assert widths == {eng._nb_cap[0]}

    def test_decode_newest_block_at_floor_budget(self, params, profile):
        """Regression: at the minimum budget (floor == block -> exactly one
        block per kv head) decode must attend the block holding the token
        just written.  The old `[0] + recent(n-1)` selection attended ONLY
        the sink at n == 1, silently losing recency/causality."""
        eng = Engine(CFG, params,
                     EngineConfig(attention="sparse", budget_per_head=128,
                                  allocator="uniform", max_seq_len=512,
                                  num_slots=1),
                     profile=profile)
        for nkv in (1, 2, 3, 4):
            ids = eng.decode_block_ids(nkv * 128)
            assert ((ids >= 0).sum(-1) == 1).all()    # floor budget: 1 block
            assert (ids[..., 0] == nkv - 1).all()     # ...and it's the newest
        # at any budget, the newest block is in every head's selection
        eng2 = Engine(CFG, params,
                      EngineConfig(attention="sparse", budget_per_head=256,
                                   max_seq_len=512, num_slots=1),
                      profile=profile)
        ids = eng2.decode_block_ids(512)
        assert (ids == 512 // 128 - 1).any(-1).all()


class TestPagedLayout:
    """cache_layout="paged" (the default) vs the contiguous parity
    baseline: identical greedy tokens on the chunked-prefill + decode
    serving path, and token-granular admission."""

    @pytest.mark.parametrize("attn,pattern,mode", [
        ("sparse", "G", "chunked"),      # S-HPLB budgeted decode
        ("dense", "G", "chunked"),       # dense baseline
        ("dense", "GL", "chunked"),      # windowed (local) layers
        ("sparse", "G", "monolithic"),   # whole-prompt scatter merge
    ])
    def test_paged_matches_contiguous_serve(self, params, profile, attn,
                                            pattern, mode):
        cfg = (CFG if pattern == "G"
               else TransformerConfig(
                   num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=256, layer_loop="unroll",
                   attn_pattern=pattern, local_window=160))
        p = params if pattern == "G" else init_params(
            jax.random.PRNGKey(0), cfg)
        prompts = [np.random.default_rng(i).integers(0, 256, size=(n,))
                   for i, n in enumerate((40, 300, 130, 70))]
        sp = SamplingParams(max_tokens=8)  # greedy
        outs = {}
        for layout in ("contiguous", "paged"):
            eng = Engine(
                cfg, p,
                EngineConfig(attention=attn, budget_per_head=512,
                             max_seq_len=512, num_slots=4,
                             prefill_mode=mode, cache_layout=layout),
                profile=profile if attn == "sparse" else None)
            outs[layout] = [r.generated for r in eng.serve(prompts, sp)]
        assert outs["paged"] == outs["contiguous"]

    def test_paged_admission_is_block_granular(self, params, profile):
        """With a pool smaller than num_slots * max_seq_len, admission is
        bounded by BLOCKS (token-granular), not slots — and everything
        still drains with blocks conserved."""
        eng = Engine(CFG, params,
                     EngineConfig(attention="sparse", budget_per_head=256,
                                  max_seq_len=512, num_slots=4,
                                  num_kv_blocks=6),  # 768 tokens of HBM
                     profile=profile)
        prompts = [np.random.default_rng(i).integers(0, 256, size=(300,))
                   for i in range(4)]
        done = eng.serve(prompts, SamplingParams(max_tokens=4))
        assert len(done) == 4 and all(len(r.generated) == 4 for r in done)
        alloc = eng.kv.alloc
        assert alloc.free_blocks == alloc.num_blocks == 6
        assert alloc.conserves()

    def test_paged_pool_is_token_not_slot_bound(self, params, profile):
        """The same pool bytes hold MORE short sequences than the
        contiguous layout's slot count — the capacity headline, at engine
        granularity (benchmarks/serving.py measures the full curve)."""
        # contiguous: 2 slots x 512 tokens = 8 blocks of HBM, 2 sequences
        # paged: the same 8 blocks hold 4 x (70 + 8) -> 4 x 1 block
        eng = Engine(CFG, params,
                     EngineConfig(attention="sparse", budget_per_head=256,
                                  max_seq_len=512, num_slots=4,
                                  num_kv_blocks=8),
                     profile=profile)
        b = eng.make_batcher()
        pf, df = eng.step_fns(SamplingParams(max_tokens=8))
        for i in range(4):
            b.submit(Request(rid=i,
                             prompt=np.arange(70 + i) % 256,
                             sampling=SamplingParams(max_tokens=8)))
        peak = 0
        while b.busy:
            b.tick(pf, df)
            peak = max(peak, len(b._slot_of))   # sequences resident at once
        assert b.stats.completed == 4
        # all four were resident at once on 2-contiguous-slots' bytes
        assert peak == 4


def _fake_fns(first_token=1, decode_token=1):
    calls = {"prefill": 0, "decode": 0}

    def prefill(toks, slot, q_offset, is_final, prompt_len):
        calls["prefill"] += 1
        return first_token if is_final else None

    def decode(slots, toks, pos):
        calls["decode"] += 1
        return np.full(len(slots), decode_token, np.int32)

    return prefill, decode, calls


class TestPackedDecodePath:
    """Engine-side contracts of the cost-packed ragged decode worklists
    (DESIGN.md §2.8): bounded host caches, pow2 item buckets, plan reuse
    across ticks, pipelined prefetch, and bubble telemetry."""

    def _engine(self, params, profile, **kw):
        base = dict(attention="sparse", budget_per_head=256,
                    max_seq_len=512, num_slots=4)
        base.update(kw)
        return Engine(CFG, params, EngineConfig(**base), profile=profile)

    def test_worklists_cache_keyed_by_bucket(self, params, profile):
        """Raw seq_len keys grew unboundedly under varied traffic; bucket
        keys cap the cache at the pow2 bucket set."""
        eng = self._engine(params, profile)
        for n in (10, 23, 40, 100, 129, 129, 200, 255):
            eng.worklists_for(n)
        assert set(eng._worklists_cache) <= {(0, 128), (0, 256), (0, 512)}

    def test_decode_ids_memo_is_bounded(self, params, profile):
        eng = self._engine(params, profile)
        cap = eng.ecfg.max_seq_len // eng.ecfg.block
        for nb in list(range(1, 20)) + [500, 10_000]:
            eng._decode_ids_for_nblocks(nb)
        assert len(eng._decode_ids_by_nblocks) <= cap

    def test_plan_cache_reused_between_boundaries_and_bounded(
            self, params, profile):
        eng = self._engine(params, profile)
        done = eng.serve([np.arange(40) % 256],
                         SamplingParams(max_tokens=12))
        assert len(done[0].generated) == 12
        s = eng.decode_stats
        # selections change only at block boundaries: nearly every tick
        # hits the memoized plan
        assert s["plan_hits"] > 0
        assert s["plan_misses"] + s["plan_prefetches"] <= 3
        assert len(eng._packed_plan_cache) <= eng._packed_plan_cap

    def test_item_buckets_are_pow2_and_few(self, params, profile):
        eng = self._engine(params, profile)
        prompts = [np.arange(n) % 256 for n in (30, 80, 150, 260)]
        eng.serve(prompts, SamplingParams(max_tokens=6))
        for flat_len in eng._decode_packed_jit:
            per_shard = flat_len // eng.ecfg.num_model_shards
            assert per_shard & (per_shard - 1) == 0, flat_len
        assert len(eng._decode_packed_jit) <= 4

    def test_bubble_stats_emitted(self, params, profile):
        eng = self._engine(params, profile)
        eng.serve([np.arange(60) % 256, np.arange(30) % 256],
                  SamplingParams(max_tokens=8))
        st = eng.decode_bubble_stats
        assert st["ticks"] > 0
        assert 0.0 <= st["padding_waste"] < 1.0
        assert 0.0 <= st["padded_path_waste"] < 1.0
        # the packed grid never exceeds the padded baseline's
        assert st["grid_vs_padded"] <= 1.0 + 1e-9
        assert st["mean_imbalance"] >= 1.0
        assert st["last_tick"]["real_items"] > 0

    def test_prefetch_plans_next_tick(self, params, profile):
        """The engine's pipelined host planning builds the next tick's
        worklist from the scheduler preview while the device step is in
        flight — observable as prefetch builds at block boundaries."""
        eng = self._engine(params, profile)
        # 124-token prompt: decode crosses the 128 boundary on tick ~4, so
        # the preview sees the new block count one tick early
        eng.serve([np.arange(124) % 256], SamplingParams(max_tokens=10))
        s = eng.decode_stats
        assert s["plan_prefetches"] >= 1
        # prefetched signatures must then HIT (the preview was correct)
        assert s["plan_misses"] <= 1


class TestSchedulerPreview:
    def test_preview_matches_next_tick_positions(self):
        prefill, decode, calls = _fake_fns()
        b = ContinuousBatcher(num_slots=2, num_blocks=64, max_seq_len=256)
        seen = []

        def decode_with_preview(slots, toks, pos):
            seen.append((tuple(slots), tuple(int(p) for p in pos),
                         b.preview_next_decode()))
            return decode(slots, toks, pos)

        for i in range(2):
            b.submit(Request(rid=i, prompt=np.arange(10),
                             sampling=SamplingParams(max_tokens=4)))
        b.run(prefill, decode_with_preview)
        for i in range(len(seen) - 1):
            _, _, preview = seen[i]
            nxt_slots, nxt_pos, _ = seen[i + 1]
            if preview is None:
                continue
            pslots, ppos = preview
            # the preview predicts the next tick exactly whenever no
            # completion/admission changed the batch in between
            if tuple(pslots) == nxt_slots:
                assert tuple(ppos)[:len(nxt_pos)] == nxt_pos

    def test_preview_none_when_idle(self):
        b = ContinuousBatcher(num_slots=2, num_blocks=64, max_seq_len=256)
        assert b.preview_next_decode() is None


class TestScheduler:
    def test_admission_respects_slots(self):
        prefill, decode, calls = _fake_fns()
        b = ContinuousBatcher(num_slots=2, num_blocks=64, max_seq_len=256)
        for i in range(5):
            b.submit(Request(rid=i, prompt=np.arange(10),
                             sampling=SamplingParams(max_tokens=3)))
        done = b.run(prefill, decode)
        assert len(done) == 5
        assert calls["prefill"] == 5
        assert b.stats.completed == 5
        assert not b.busy

    def test_rejected_requests_are_returned(self):
        """Over-length requests are refused but NOT dropped: they come back
        flagged, so completed + rejected == submitted and result lists zip
        with the inputs."""
        prefill, decode, _ = _fake_fns()
        b = ContinuousBatcher(num_slots=2, num_blocks=64, max_seq_len=64)
        b.submit(Request(rid=0, prompt=np.arange(100),
                         sampling=SamplingParams(max_tokens=10)))
        b.submit(Request(rid=1, prompt=np.arange(10),
                         sampling=SamplingParams(max_tokens=3)))
        done = b.run(prefill, decode)
        assert len(done) == 2 and not b.busy
        by_rid = {r.rid: r for r in done}
        assert by_rid[0].rejected and by_rid[0].done
        assert by_rid[0].generated == []
        assert not by_rid[1].rejected
        assert b.stats.completed + b.stats.rejected == 2

    def test_stop_token_at_prefill_ends_request(self):
        """A prefill that samples the stop token must finish the request —
        the completion check is shared with the decode path."""
        stop = 7
        prefill, decode, calls = _fake_fns(first_token=stop)
        b = ContinuousBatcher(num_slots=2, num_blocks=64, max_seq_len=256)
        b.submit(Request(rid=0, prompt=np.arange(10),
                         sampling=SamplingParams(max_tokens=50,
                                                 stop_token=stop)))
        done = b.run(prefill, decode)
        assert len(done) == 1
        assert done[0].generated == [stop]
        assert calls["decode"] == 0  # never decoded past the stop

    def test_max_tokens_one_samples_exactly_one(self):
        prefill, decode, calls = _fake_fns()
        b = ContinuousBatcher(num_slots=2, num_blocks=64, max_seq_len=256)
        b.submit(Request(rid=0, prompt=np.arange(10),
                         sampling=SamplingParams(max_tokens=1)))
        done = b.run(prefill, decode)
        assert done[0].generated == [1]
        assert calls["decode"] == 0

    def test_chunked_prefill_covers_prompt_block_aligned(self):
        """Token-budget ticks split the prompt into block-aligned chunks
        (only the final chunk may be partial) that exactly cover it."""
        chunks = []

        def prefill(toks, slot, q_offset, is_final, prompt_len):
            chunks.append((q_offset, toks.shape[-1], is_final))
            return 1 if is_final else None

        def decode(slots, toks, pos):
            return np.ones(len(slots), np.int32)

        b = ContinuousBatcher(num_slots=2, num_blocks=64, max_seq_len=1024,
                              block=128, token_budget=256)
        b.submit(Request(rid=0, prompt=np.arange(700),
                         sampling=SamplingParams(max_tokens=2)))
        b.run(prefill, decode)
        assert sum(c for _, c, _ in chunks) == 700
        pos = 0
        for off, c, final in chunks:
            assert off == pos and off % 128 == 0
            if not final:
                assert c % 128 == 0
            pos += c
        assert chunks[-1][2] and b.stats.prefill_chunks == len(chunks)


class TestBlockAllocator:
    def test_admit_free_cycle(self):
        a = BlockAllocator(num_blocks=10, block=128)
        a.admit(1, 500)   # 4 blocks
        a.admit(2, 700)   # 6 blocks
        assert a.free_blocks == 0
        assert not a.can_admit(1)
        a.free(1)
        assert a.free_blocks == 4
        a.admit(3, 512)
        assert a.free_blocks == 0
        assert a.conserves()

    def test_append_token_grows_at_boundary(self):
        a = BlockAllocator(num_blocks=4, block=128)
        a.admit(1, 128, max_new_tokens=2)
        assert len(a.table(1)) == 1
        a.append_token(1)   # token 129 crosses into block 2
        assert len(a.table(1)) == 2
        a.append_token(1)   # token 130: no growth mid-block
        assert len(a.table(1)) == 2
        assert a.seq_tokens(1) == 130 and a.conserves()

    def test_reservation_guards_decode_growth(self):
        """Admission headroom counts reserved-but-unmapped blocks, so a
        later arrival can never steal the blocks an active sequence's
        generation is entitled to."""
        a = BlockAllocator(num_blocks=3, block=128)
        a.admit(1, 128, max_new_tokens=128)  # maps 1, reserves 2
        assert a.free_blocks == 2            # physically free...
        assert a.available_blocks == 1       # ...but one is spoken for
        assert not a.can_admit(200)          # 2 blocks > 1 available
        a.admit(2, 128)
        with pytest.raises(MemoryError):
            a.admit(3, 1)
        a.append_token(1)                    # the reserved block maps fine
        assert len(a.table(1)) == 2

    def test_exhaustion_raises(self):
        a = BlockAllocator(num_blocks=2, block=128)
        with pytest.raises(MemoryError):
            a.admit(1, 1000)


class TestSampler:
    def test_greedy_is_argmax(self):
        logits = jnp.asarray([[1.0, 5.0, 2.0], [3.0, 0.0, 9.0]])
        t = sample(logits, jax.random.PRNGKey(0),
                   SamplingParams(temperature=0.0))
        assert t.tolist() == [1, 2]

    def test_topk_restricts_support(self):
        logits = jnp.asarray([[0.0, 10.0, 9.0, -5.0]])
        for seed in range(20):
            t = sample(logits, jax.random.PRNGKey(seed),
                       SamplingParams(temperature=1.0, top_k=2))
            assert int(t[0]) in (1, 2)

    def test_top_p_restricts_support(self):
        logits = jnp.asarray([[10.0, 1.0, 0.5, 0.2]])
        for seed in range(20):
            t = sample(logits, jax.random.PRNGKey(seed),
                       SamplingParams(temperature=1.0, top_p=0.5))
            assert int(t[0]) == 0
