"""Chunked prefill: model-layer partial-prefill equivalence, chunk
work-list slicing, and the decode active-slot write mask."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.attention.worklist_jnp import causal_items, worklist_attention
from repro.core.worklist import (
    F_KVBLK,
    F_QBLK,
    F_VALID,
    chunk_item_counts,
    chunk_items,
)
from repro.models import transformer as tfm
from repro.models.transformer import TransformerConfig

CFG = TransformerConfig(num_layers=2, d_model=64, num_heads=4,
                        num_kv_heads=2, d_ff=128, vocab_size=256,
                        layer_loop="unroll", dtype=jnp.float32,
                        block_q=16, block_kv=16)

BLOCK = 16
SMAX = 128
SLOTS = 3


@pytest.fixture(scope="module")
def params():
    return tfm.init_params(jax.random.PRNGKey(1), CFG)


def _full_causal_items(cfg, seq_len):
    nq = -(-seq_len // BLOCK)
    kv_of = np.arange(cfg.num_heads) // cfg.group_size
    return causal_items(cfg.num_heads, nq, kv_of)


def _run_chunks(params, cfg, tokens, slot, chunk_lens, sparse=False):
    """Drive tfm.prefill_chunk over a chunk split; returns (logits, cache)."""
    cache = tfm.init_cache(cfg, SLOTS, SMAX)
    S = sum(chunk_lens)
    full = _full_causal_items(cfg, S) if sparse else None
    off = 0
    logits = None
    for c in chunk_lens:
        toks = tokens[off:off + c][None]
        items = None
        if sparse:
            nqc = -(-c // BLOCK)
            it = chunk_items(full, off // BLOCK, nqc,
                             pad_to=len(full))
            items = np.stack([it] * cfg.num_layers)
        logits, cache = tfm.prefill_chunk(
            params, cache, jnp.asarray(toks), slot, off, cfg,
            kv_len=off + c, sparse_items=items, last_index=c - 1)
        off += c
    return logits, cache


@pytest.mark.parametrize("sparse", [False, True])
@pytest.mark.parametrize("chunk_lens", [(80,), (32, 48), (16, 32, 32), (32, 33)])
def test_prefill_chunk_matches_monolithic(params, sparse, chunk_lens):
    """Any block-aligned chunk split reproduces the monolithic prefill:
    same last-token logits, same cache rows."""
    S = sum(chunk_lens)
    tokens = np.random.default_rng(0).integers(0, 256, size=(S,)).astype(
        np.int32)
    items = ([_full_causal_items(CFG, S)] * CFG.num_layers) if sparse else None
    ref_logits, ref_cache = tfm.prefill(
        params, jnp.asarray(tokens[None]), CFG, cache_len=SMAX,
        sparse_items=items)
    slot = 1
    logits, cache = _run_chunks(params, CFG, tokens, slot, chunk_lens,
                                sparse=sparse)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)
    got = np.asarray(cache)[:, :, slot, :, :S]
    want = np.asarray(ref_cache)[:, :, 0, :, :S]
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_prefill_chunk_untouched_slots(params):
    """Chunked prefill into one slot leaves every other slot's cache rows
    exactly as they were."""
    tokens = np.random.default_rng(1).integers(0, 256, size=(48,))
    cache0 = tfm.init_cache(CFG, SLOTS, SMAX) + 3.0
    cache = cache0
    off = 0
    for c in (16, 32):
        _, cache = tfm.prefill_chunk(
            params, cache, jnp.asarray(tokens[off:off + c][None]), 2, off,
            CFG, kv_len=off + c, last_index=c - 1)
        off += c
    got = np.asarray(cache)
    want = np.asarray(cache0)
    for s in range(SLOTS):
        if s == 2:
            continue
        np.testing.assert_array_equal(got[:, :, s], want[:, :, s])


def test_prefill_chunk_scan_loop_mode(params):
    """The lax.scan layer loop lowers the same chunked math as unroll."""
    cfg_scan = dataclasses.replace(CFG, layer_loop="scan")
    params_scan = tfm.init_params(jax.random.PRNGKey(1), cfg_scan)
    tokens = np.random.default_rng(2).integers(0, 256, size=(64,)).astype(
        np.int32)
    ref_logits, _ = tfm.prefill(params_scan, jnp.asarray(tokens[None]),
                                cfg_scan, cache_len=SMAX)
    logits, _ = _run_chunks(params_scan, cfg_scan, tokens, 0, (32, 32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)


def test_prefill_chunk_local_window(params):
    """Sliding-window layers mask by GLOBAL position across chunks."""
    cfg_l = dataclasses.replace(CFG, attn_pattern="GL", local_window=24)
    params_l = tfm.init_params(jax.random.PRNGKey(1), cfg_l)
    tokens = np.random.default_rng(3).integers(0, 256, size=(64,)).astype(
        np.int32)
    ref_logits, _ = tfm.prefill(params_l, jnp.asarray(tokens[None]), cfg_l,
                                cache_len=SMAX)
    logits, _ = _run_chunks(params_l, cfg_l, tokens, 0, (16, 48))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               rtol=2e-4, atol=2e-4)


class TestWorklistChunkView:
    def test_chunk_items_slices_and_remaps(self):
        it = causal_items(2, 4)  # 2 heads, 4 q blocks, full causal
        sl = chunk_items(it, 2, 2)
        assert (sl[:, F_VALID] == 1).all()
        assert set(sl[:, F_QBLK].tolist()) == {0, 1}     # chunk-local
        assert sl[:, F_KVBLK].max() == 3                 # kv stays global
        # q_blk 2 has 3 causal kv blocks, q_blk 3 has 4; two heads
        assert len(sl) == 2 * (3 + 4)

    def test_chunk_items_padding_convention(self):
        it = causal_items(1, 4)
        sl = chunk_items(it, 1, 1, pad_to=8)
        assert sl.shape == (8, it.shape[-1])
        assert (sl[:2, F_VALID] == 1).all()
        assert (sl[2:, F_VALID] == 0).all()
        # padding replicates the last real item's indices
        assert (sl[2:, F_QBLK] == sl[1, F_QBLK]).all()

    def test_chunk_items_cap_overflow_raises(self):
        it = causal_items(1, 4)
        with pytest.raises(ValueError):
            chunk_items(it, 0, 4, pad_to=2)

    def test_chunk_item_counts(self):
        it = causal_items(2, 4)
        counts = chunk_item_counts(it, 4)
        assert counts.tolist() == [2, 4, 6, 8]

    def test_worklist_q_offset_matches_full(self):
        """Executing the chunk slice at q_offset reproduces the full
        work-list rows for that chunk."""
        rng = np.random.default_rng(0)
        H, Hkv, S, D = 2, 1, 64, 8
        q = jnp.asarray(rng.normal(size=(H, S, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(Hkv, S, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(Hkv, S, D)), jnp.float32)
        items = causal_items(H, S // 16, np.zeros(H, np.int64))
        full = worklist_attention(q, k, v, jnp.asarray(items),
                                  block_q=16, block_kv=16)
        off, c = 32, 32
        sl = chunk_items(items, off // 16, c // 16, pad_to=len(items))
        part = worklist_attention(q[:, off:off + c], k, v, jnp.asarray(sl),
                                  block_q=16, block_kv=16,
                                  q_offset=off, kv_len=S)
        np.testing.assert_allclose(np.asarray(part),
                                   np.asarray(full)[:, off:off + c],
                                   rtol=1e-6, atol=1e-6)


def test_decode_step_active_mask_protects_slots(params):
    """A batched decode step must not mutate cache rows of slots marked
    inactive (freed, or mid-chunked-prefill in a mixed tick)."""
    cache = tfm.init_cache(CFG, SLOTS, SMAX) + 1.0
    token = jnp.asarray(np.arange(SLOTS), jnp.int32)
    pos = jnp.asarray([5, 0, 9], jnp.int32)
    active = jnp.asarray([True, False, False])
    _, new_cache = tfm.decode_step(params, cache, token, pos, CFG,
                                   cache_len=pos + 1, active=active)
    got = np.asarray(new_cache)
    want = np.asarray(cache)
    # inactive slots bit-identical everywhere
    np.testing.assert_array_equal(got[:, :, 1], want[:, :, 1])
    np.testing.assert_array_equal(got[:, :, 2], want[:, :, 2])
    # the active slot DID write its row
    assert not np.array_equal(got[:, :, 0, :, 5], want[:, :, 0, :, 5])
