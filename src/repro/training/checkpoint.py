"""Fault-tolerant checkpointing: atomic writes, async save, keep-N GC,
resume-from-latest, and ELASTIC restore across different mesh shapes.

Format: one ``.npz`` per checkpoint holding the flattened pytree (keys are
``/``-joined paths) + a JSON sidecar with step/metadata.  Writes go to a
temp name in the same directory and are ``os.rename``d into place — a crash
mid-save can never corrupt the latest checkpoint (restart picks up the
previous one).  ``CheckpointManager.save(..., blocking=False)`` runs the
serialization on a daemon thread (training continues; ``wait()`` joins).

Elastic restore: arrays are saved as full (unsharded) host arrays; loading
under a *different* mesh simply re-shards via ``jax.device_put`` with the
new sharding — tested 1<->4<->8 host-device configs in
``tests/test_checkpoint.py``.  For multi-TB models a production deployment
would swap the .npz backend for a tensor-store without touching the
manager logic.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time

import numpy as np
import jax

from repro.utils.logging import get_logger

log = get_logger("checkpoint")

_SEP = "/"
_BF16_SUFFIX = "#bf16"  # npz cannot store ml_dtypes.bfloat16; view as uint16


def _flatten(tree) -> dict[str, np.ndarray]:
    import ml_dtypes
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == ml_dtypes.bfloat16:
            key += _BF16_SUFFIX
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def _decode_flat(flat: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    import ml_dtypes
    out = {}
    for k, v in flat.items():
        if k.endswith(_BF16_SUFFIX):
            out[k[: -len(_BF16_SUFFIX)]] = v.view(ml_dtypes.bfloat16)
        else:
            out[k] = v
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def _unflatten_into(template, flat: dict[str, np.ndarray], shardings=None):
    paths, tdef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    shard_list = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(paths))
    for (path, leaf), shard in zip(paths, shard_list):
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.device_put(arr))
    return tdef.unflatten(leaves)


class CheckpointManager:
    """Directory of ``step_<N>.npz`` checkpoints with keep-N GC."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- paths -------------------------------------------------------------
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}.npz")

    def steps(self) -> list[int]:
        out = []
        for f in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)\.npz", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, metadata: dict | None = None,
             blocking: bool = True):
        """Atomic (temp+rename) save; async when ``blocking=False``."""
        # materialize to host BEFORE handing to the thread (device buffers
        # may be donated/overwritten by subsequent steps)
        flat = _flatten(jax.device_get(tree))
        meta = dict(metadata or {}, step=step, time=time.time())

        def _write():
            tmp = self._path(step) + f".tmp.{os.getpid()}"
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
            os.rename(tmp, self._path(step))
            with open(os.path.join(self.dir, "metadata.json"), "w") as f:
                json.dump(meta, f)
            self._gc()
            log.info("saved checkpoint step=%d (%d arrays)", step, len(flat))

        self.wait()
        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            try:
                os.remove(self._path(s))
            except OSError:
                pass

    # -- restore -------------------------------------------------------------
    def restore(self, step: int, template, shardings=None):
        """Load ``step`` into the structure of ``template``.

        ``shardings``: optional pytree of Sharding matching template — the
        ELASTIC path: arrays are placed per the *current* mesh regardless of
        the mesh they were saved under.
        """
        with np.load(self._path(step), allow_pickle=False) as z:
            flat = _decode_flat({k: z[k] for k in z.files})
        return _unflatten_into(template, flat, shardings)

    def restore_latest(self, template, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, template, shardings)
