"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron [arXiv:2407.14679; hf]."""
from repro.configs.base import ArchSpec
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="minitron-8b",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=16384, vocab_size=256000, head_dim=128,
    attn_pattern="G", tie_embeddings=True,
)

SMOKE = TransformerConfig(
    name="minitron-8b-smoke",
    num_layers=2, d_model=128, num_heads=8, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16,
    attn_pattern="G", tie_embeddings=True,
)

SPEC = ArchSpec(
    arch_id="minitron-8b", family="dense", module="transformer",
    full=FULL, smoke=SMOKE, hplb="full", long_mode="sparse",
    source="arXiv:2407.14679; hf",
)
