"""Quickstart: the S-HPLB pipeline end to end on one host, in five steps.

    PYTHONPATH=src python examples/quickstart.py

1. profile per-head sparsity offline (synthetic calibration curves here;
   ``benchmarks/common.tiny_lm_profile`` shows the real-attention-map path);
2. allocate per-head budgets with the paper's max-min shifting;
3. balance heads across devices (LPT / KK+refine);
4. build the flattened SPMD work-lists;
5. execute sparse attention with the work-list kernel and compare against
   full attention.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.attention import flash_attention_ref, strided_policy
from repro.core import (
    best_partition,
    imbalance_ratio,
    make_plan,
    maxmin_allocation,
    naive_partition,
    plan_summary,
    synthetic_head_curves,
    uniform_allocation,
    worklist_from_budgets,
)
from repro.attention.worklist_jnp import worklist_attention

H, HKV, SEQ, DH, DEVICES, K = 16, 8, 2048, 64, 4, 256

print("=== 1. offline sparsity profile ===")
prof = synthetic_head_curves(1, H)
print(f"heads: {H}; budget heterogeneity at p=0.9: "
      f"{prof.heterogeneity(0):.2f}x")

print("\n=== 2. max-min budget allocation (paper §3.2) ===")
uni = uniform_allocation(prof, layer=0, k=K, seq_len=SEQ)
mm = maxmin_allocation(prof, layer=0, total=H * K, seq_len=SEQ)
print(f"uniform top-k:   min recovery {uni.min_recovery:.3f}")
print(f"max-min shifted: min recovery {mm.min_recovery:.3f} "
      f"({mm.iterations} transfers, same total budget)")

print("\n=== 3. head-parallel load balance (paper §3.3) ===")
naive = naive_partition(mm.budgets, DEVICES, mode="contiguous")
lb = best_partition(mm.budgets, DEVICES)
print(f"naive HP:  imbalance {naive.imbalance:.2f}  loads {naive.loads}")
print(f"S-HPLB:    imbalance {lb.imbalance:.2f}  loads {lb.loads}")

print("\n=== 4. whole-model plan + work-lists ===")
plan = make_plan(prof, num_devices=DEVICES, num_kv_heads=HKV,
                 seq_len=SEQ, total_budget_per_head=K)
print({k: round(v, 3) if isinstance(v, float) else v
       for k, v in plan_summary(plan).items()})
wl = worklist_from_budgets(
    plan.layers[0].budgets, num_devices=DEVICES, seq_len=SEQ, block=128,
    policy_fn=strided_policy, group_size=H // HKV)
print(f"work-list: padded length {wl.padded_length} per device "
      f"(waste {wl.padding_waste:.1%}, imbalance {wl.imbalance:.3f})")

print("\n=== 5. execute sparse attention vs full ===")
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (H, SEQ, DH), jnp.float32)
k = jax.random.normal(ks[1], (HKV, SEQ, DH), jnp.float32)
v = jax.random.normal(ks[2], (HKV, SEQ, DH), jnp.float32)
# single-host: run each device's list against its head slice
heads_per_dev = H // DEVICES
outs = []
for d in range(DEVICES):
    # device d's q slice: slot order == plan permutation order
    sl = slice(d * heads_per_dev, (d + 1) * heads_per_dev)
    qd = q[plan.layers[0].perm[sl]]
    kd = k  # kv groups colocated: slice via plan.kv_perm in production
    o = worklist_attention(qd, k[plan.layers[0].kv_perm[
        d * (HKV // DEVICES):(d + 1) * (HKV // DEVICES)]],
        v[plan.layers[0].kv_perm[
            d * (HKV // DEVICES):(d + 1) * (HKV // DEVICES)]],
        jnp.asarray(wl.items[d]))
    outs.append(o)
sparse_out = jnp.concatenate(outs, axis=0)  # slot order
full_out = flash_attention_ref(q, k, v, causal=True)[plan.layers[0].perm]
rel = float(jnp.linalg.norm(sparse_out - full_out)
            / jnp.linalg.norm(full_out))
tiles_full = H * (SEQ // 128) * (SEQ // 128 + 1) // 2
print(f"sparse tiles {wl.total_real_items} vs full {tiles_full} "
      f"({wl.total_real_items / tiles_full:.1%} of compute); "
      f"output rel-err vs full attention: {rel:.3f}")
print("(note: RANDOM weights have diffuse attention, so a 12.5% budget"
      " keeps ~22% of the mass — on trained models the profiled budgets"
      " recover >90% (see benchmarks/accuracy_ruler.py))")
print("\nquickstart OK")
