"""Flattened sparse-attention work-lists (TPU adaptation, DESIGN.md §2.2).

Under XLA SPMD every device executes the same program, so heterogeneous
per-head sparse attention must be expressed as a *flattened work-list*:

    one work item = one (head_slot, q_block, kv_block) flash-attention tile.

Each device (model-axis shard) owns the items of its assigned head slots;
lists are padded to the maximum per-device length ``L_pad = max_d L_d`` so
they stack into one ``[D, L_pad, ITEM_FIELDS]`` int32 array that shards
cleanly over the ``model`` axis inside ``shard_map``.  S-HPLB's objective
``min max_d L_d`` therefore *directly* minimizes the compiled Pallas grid.

Item encoding (int32), consumed by the sparse-prefill kernel via scalar
prefetch:

    [:, 0] head_local   — q-head index within the device's shard
    [:, 1] q_blk        — query block index
    [:, 2] kv_blk       — kv block index to stream for this step
    [:, 3] is_first     — 1 => reset the online-softmax accumulator
    [:, 4] is_last      — 1 => normalize + write back the output tile
    [:, 5] valid        — 0 => padding item (no compute, no writeback)
    [:, 6] kv_head      — kv-head index within the device's shard (GQA)

Padding rows REPLICATE the last real item's indices (with valid=0): the
Pallas output tile is flushed on block-index *change*, so padding must not
redirect the out index map to a tile that was already finalized.

Items of one (head, q_blk) are CONTIGUOUS and in ascending kv_blk order —
TPU Pallas grids execute sequentially per core, which legalizes the
cross-item accumulator in VMEM scratch.

Block selection: which kv blocks a (head, q_blk) attends to is produced by a
selection policy (``repro.attention.policies``) given the head's token budget
from the HPLB plan.  This module handles budget -> block-count conversion,
list construction, padding, and cost accounting.
"""
from __future__ import annotations

import dataclasses

import numpy as np

ITEM_FIELDS = 7
F_HEAD, F_QBLK, F_KVBLK, F_FIRST, F_LAST, F_VALID, F_KVHEAD = range(ITEM_FIELDS)

# Decode work items (DESIGN.md §2.8): one item = one (batch_row, kv_head,
# kv_block) matvec tile.  The constants live here (host-side, numpy-only)
# and the Pallas/jnp executors import them, so builders never depend on jax.
DEC_FIELDS = 6
D_BATCH, D_KVHEAD, D_KVBLK, D_FIRST, D_LAST, D_VALID = range(DEC_FIELDS)


def pow2_bucket(n: int, lo: int = 8, hi: int | None = None) -> int:
    """Smallest power of two >= ``n`` (floored at ``lo``, capped at ``hi``).

    The decode item tables are padded to these buckets so mixed-length
    continuous-batching ticks reuse O(log worst-case) compiled programs
    instead of one per distinct item count — the same policy the engine's
    prefill buckets use for prompt lengths.
    """
    b = max(1, int(lo))
    n = max(int(n), 1)
    while b < n:
        b *= 2
    return b if hi is None else min(b, max(int(hi), int(lo)))


def blocks_for_budget(budgets: np.ndarray, block: int) -> np.ndarray:
    """Token budgets -> per-head kv-block counts (ceil)."""
    b = np.asarray(budgets, dtype=np.int64)
    return np.maximum(-(-b // block), 1)


@dataclasses.dataclass
class WorkList:
    """Per-device padded work-lists for one attention layer.

    items:      ``[D, L_pad, ITEM_FIELDS]`` int32.
    lengths:    ``[D]`` true (unpadded) item counts.
    num_q_blocks, num_kv_blocks, block: geometry.
    """

    items: np.ndarray
    lengths: np.ndarray
    num_q_blocks: int
    num_kv_blocks: int
    block: int

    @property
    def num_devices(self) -> int:
        return self.items.shape[0]

    @property
    def padded_length(self) -> int:
        return self.items.shape[1]

    @property
    def total_real_items(self) -> int:
        return int(self.lengths.sum())

    @property
    def padded_total(self) -> int:
        return self.padded_length * self.num_devices

    @property
    def padding_waste(self) -> float:
        """Fraction of grid steps that are padding — the SPMD bubble that
        S-HPLB minimizes (= the paper's resource wastage, exactly)."""
        tot = self.padded_total
        return 1.0 - self.total_real_items / tot if tot else 0.0

    @property
    def imbalance(self) -> float:
        mean = float(self.lengths.mean())
        return float(self.lengths.max() / mean) if mean > 0 else 1.0


def _items_for_head(
    head_local: int,
    kv_head_local: int,
    q_blocks: int,
    kv_block_ids: list[np.ndarray],
) -> np.ndarray:
    """Items for one head given its selected kv blocks per q block."""
    rows = []
    for qb in range(q_blocks):
        sel = np.asarray(kv_block_ids[qb], dtype=np.int64)
        n = len(sel)
        if n == 0:
            continue
        it = np.zeros((n, ITEM_FIELDS), dtype=np.int32)
        it[:, F_HEAD] = head_local
        it[:, F_QBLK] = qb
        it[:, F_KVBLK] = np.sort(sel)
        it[0, F_FIRST] = 1
        it[-1, F_LAST] = 1
        it[:, F_VALID] = 1
        it[:, F_KVHEAD] = kv_head_local
        rows.append(it)
    if not rows:
        return np.zeros((0, ITEM_FIELDS), dtype=np.int32)
    return np.concatenate(rows, axis=0)


def build_worklist(
    selections: list[list[np.ndarray]],
    device_of_head: np.ndarray,
    num_devices: int,
    num_q_blocks: int,
    num_kv_blocks: int,
    block: int,
    pad_multiple: int = 8,
    kv_head_of_head: np.ndarray | None = None,
    kv_local: bool = True,
) -> WorkList:
    """Build per-device padded work-lists.

    Parameters
    ----------
    selections:
        ``selections[h][qb]`` = array of kv block ids head ``h`` attends to
        at query block ``qb`` (already budget-limited by the policy).
    device_of_head:
        ``[H]`` device index per head (slot order from the HPLB plan:
        ``slot // heads_per_device``).
    pad_multiple:
        pad L_pad up so the kernel grid length is a friendly multiple.
    kv_head_of_head:
        ``[H]`` kv-head per q-head slot (GQA).  Default: identity (MHA).
    kv_local:
        True (kv_group mode): kv heads are SHARDED with their q heads; item
        kv indices are remapped to device-local first-seen order.
        False (kv_replication mode): kv heads are replicated on every
        device; item kv indices stay GLOBAL.
    """
    H = len(selections)
    if kv_head_of_head is None:
        kv_head_of_head = np.arange(H, dtype=np.int64)
    per_dev: list[list[np.ndarray]] = [[] for _ in range(num_devices)]
    heads_seen_per_dev = np.zeros(num_devices, dtype=np.int64)
    kv_local_map: list[dict[int, int]] = [dict() for _ in range(num_devices)]
    for h in range(H):
        d = int(device_of_head[h])
        head_local = int(heads_seen_per_dev[d])
        heads_seen_per_dev[d] += 1
        kv_g = int(kv_head_of_head[h])
        if kv_local:
            if kv_g not in kv_local_map[d]:
                kv_local_map[d][kv_g] = len(kv_local_map[d])
            kv_idx = kv_local_map[d][kv_g]
        else:
            kv_idx = kv_g
        it = _items_for_head(head_local, kv_idx, num_q_blocks, selections[h])
        if len(it):
            per_dev[d].append(it)
    dev_items = [
        np.concatenate(g, axis=0) if g else np.zeros((0, ITEM_FIELDS), np.int32)
        for g in per_dev
    ]
    lengths = np.array([len(x) for x in dev_items], dtype=np.int64)
    L_pad = int(lengths.max()) if len(lengths) else 0
    L_pad = max(pad_multiple, -(-L_pad // pad_multiple) * pad_multiple)
    items = np.zeros((num_devices, L_pad, ITEM_FIELDS), dtype=np.int32)
    for d, x in enumerate(dev_items):
        items[d, : len(x)] = x
        if len(x):
            # padding replicates the last real item's indices (valid=0):
            # keeps the Pallas out-tile index constant so the finalized tile
            # is not flushed-then-clobbered by a stray index change.
            pad_row = x[-1].copy()
            pad_row[F_FIRST] = 0
            pad_row[F_LAST] = 0
            pad_row[F_VALID] = 0
            items[d, len(x):] = pad_row
    return WorkList(
        items=items, lengths=lengths,
        num_q_blocks=num_q_blocks, num_kv_blocks=num_kv_blocks, block=block,
    )


def worklist_from_budgets(
    budgets_slot_order: np.ndarray,
    *,
    num_devices: int,
    seq_len: int,
    block: int,
    policy_fn,
    pad_multiple: int = 8,
    group_size: int = 1,
    kv_head_of_head: np.ndarray | None = None,
    kv_local: bool = True,
) -> WorkList:
    """Convenience: budgets (slot order) + a selection policy -> WorkList.

    ``policy_fn(head_slot, num_blocks_budget, num_q_blocks, num_kv_blocks)
    -> list over q_blocks of kv-block-id arrays``.  The causal structure
    (kv_blk <= q_blk) is the policy's responsibility.  ``group_size``: GQA
    query heads per kv head (kv_group mode: slot order groups them
    contiguously).  ``kv_head_of_head`` overrides the mapping (slot order)
    — required in kv_replication mode where the permutation is per-q-head;
    pair it with ``kv_local=False``.
    """
    H = len(budgets_slot_order)
    assert H % num_devices == 0
    heads_per_dev = H // num_devices
    nq = -(-seq_len // block)
    nkv = nq
    nb = blocks_for_budget(budgets_slot_order, block)
    selections = [
        policy_fn(h, int(nb[h]), nq, nkv) for h in range(H)
    ]
    device_of_head = np.arange(H) // heads_per_dev
    if kv_head_of_head is None:
        kv_head_of_head = np.arange(H) // group_size
    return build_worklist(
        selections, device_of_head, num_devices, nq, nkv, block,
        pad_multiple=pad_multiple, kv_head_of_head=kv_head_of_head,
        kv_local=kv_local,
    )


def chunk_items(items: np.ndarray, q_blk_start: int, q_blk_count: int,
                pad_to: int | None = None) -> np.ndarray:
    """Slice a flattened work-list to the q-block window ``[q_blk_start,
    q_blk_start + q_blk_count)`` — the chunked-prefill view of a full-prompt
    list (DESIGN.md §2.6).

    ``items``: one device's ``[N, ITEM_FIELDS]`` list.  Kept items have
    F_QBLK remapped to chunk-local indices; F_KVBLK stays GLOBAL (the chunk
    attends the whole resident KV prefix).  (head, q_blk) groups are kept
    intact, so the F_FIRST/F_LAST accumulator protocol survives the slice.
    ``pad_to`` pads with the last real item replicated at valid=0 (the same
    convention as :func:`build_worklist`); chunk lists padded to one width
    enter the jitted chunk prefill as DATA, so varying chunk offsets never
    recompile.
    """
    it = np.asarray(items).reshape(-1, ITEM_FIELDS)
    keep = ((it[:, F_VALID] == 1)
            & (it[:, F_QBLK] >= q_blk_start)
            & (it[:, F_QBLK] < q_blk_start + q_blk_count))
    out = it[keep].copy()
    out[:, F_QBLK] -= q_blk_start
    if pad_to is None:
        return out
    if len(out) > pad_to:
        raise ValueError(
            f"chunk work-list ({len(out)} items) exceeds pad_to={pad_to}")
    padded = np.zeros((pad_to, ITEM_FIELDS), dtype=np.int32)
    padded[: len(out)] = out
    if len(out):
        pad_row = out[-1].copy()
        pad_row[F_FIRST] = 0
        pad_row[F_LAST] = 0
        pad_row[F_VALID] = 0
        padded[len(out):] = pad_row
    return padded


def chunk_item_counts(items: np.ndarray, num_q_blocks: int) -> np.ndarray:
    """Per-q-block real-item counts of one device's list ``[N, 7]`` —
    sliding-window sums over this give the compile-time item cap for a
    chunk bucket (``Engine._chunk_item_cap``)."""
    it = np.asarray(items).reshape(-1, ITEM_FIELDS)
    real = it[it[:, F_VALID] == 1]
    return np.bincount(real[:, F_QBLK], minlength=num_q_blocks)[:num_q_blocks]


def build_row_worklist(
    selections: list[list[np.ndarray]],
    *,
    num_devices: int,
    num_q_blocks: int,
    num_kv_blocks: int,
    block: int,
    kv_head_of_head: np.ndarray | None = None,
    pad_multiple: int = 8,
) -> WorkList:
    """Row-mode work-lists: partition (head, q_block) ROWS across devices.

    Beyond-paper generalization of HPLB for archs whose head count does not
    divide the model axis (gemma3-1b: 4 heads over 16 shards; llama4: 40
    over 16): the atoms of the multiway partition are (head, q_blk) rows
    with weight = that row's tile count, balanced by the same
    best-partition machinery.  q/k/v are REPLICATED inside the island and
    each shard contributes only its rows; outputs combine by psum (disjoint
    tiles).  Item head/kv indices are GLOBAL.
    """
    from repro.core.partition import best_partition

    H = len(selections)
    if kv_head_of_head is None:
        kv_head_of_head = np.arange(H, dtype=np.int64)
    rows = []        # (h, qb, tiles)
    for h in range(H):
        for qb in range(num_q_blocks):
            n = len(selections[h][qb])
            if n:
                rows.append((h, qb, n))
    weights = np.array([r[2] for r in rows], dtype=np.int64)
    asg = best_partition(weights, num_devices)
    per_dev: list[list[np.ndarray]] = [[] for _ in range(num_devices)]
    for (h, qb, _), d in zip(rows, asg.device_of):
        sel = np.sort(np.asarray(selections[h][qb], dtype=np.int64))
        it = np.zeros((len(sel), ITEM_FIELDS), dtype=np.int32)
        it[:, F_HEAD] = h
        it[:, F_QBLK] = qb
        it[:, F_KVBLK] = sel
        it[0, F_FIRST] = 1
        it[-1, F_LAST] = 1
        it[:, F_VALID] = 1
        it[:, F_KVHEAD] = kv_head_of_head[h]
        per_dev[int(d)].append(it)
    dev_items = [
        np.concatenate(g, axis=0) if g else np.zeros((0, ITEM_FIELDS),
                                                     np.int32)
        for g in per_dev
    ]
    lengths = np.array([len(x) for x in dev_items], dtype=np.int64)
    L_pad = int(lengths.max()) if len(lengths) else 0
    L_pad = max(pad_multiple, -(-L_pad // pad_multiple) * pad_multiple)
    items = np.zeros((num_devices, L_pad, ITEM_FIELDS), dtype=np.int32)
    for d, x in enumerate(dev_items):
        items[d, : len(x)] = x
        if len(x):
            pad_row = x[-1].copy()
            pad_row[F_FIRST] = 0
            pad_row[F_LAST] = 0
            pad_row[F_VALID] = 0
            items[d, len(x):] = pad_row
    return WorkList(items=items, lengths=lengths,
                    num_q_blocks=num_q_blocks, num_kv_blocks=num_kv_blocks,
                    block=block)


# ---------------------------------------------------------------------------
# Cost-packed ragged decode worklists (DESIGN.md §2.8)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedDecodeWorkList:
    """Per-shard cost-packed decode item lists for one attention layer.

    items:   ``[D, L_pad, DEC_FIELDS]`` int32 — one (batch_row, kv_head,
             kv_block) tile per row, runs of one (row, kv_head) contiguous
             and in ascending kv_block order; padding rows replicate the
             shard's last real item with first/last/valid = 0.
    lengths: ``[D]`` true (unpadded) item counts per shard.
    """

    items: np.ndarray
    lengths: np.ndarray
    block: int

    @property
    def num_shards(self) -> int:
        return self.items.shape[0]

    @property
    def padded_length(self) -> int:
        return self.items.shape[1]

    @property
    def total_real_items(self) -> int:
        return int(self.lengths.sum())

    @property
    def padded_total(self) -> int:
        return self.padded_length * self.num_shards

    @property
    def padding_waste(self) -> float:
        """Fraction of grid steps that are padding — the decode-phase SPMD
        bubble (same definition as :class:`WorkList.padding_waste`)."""
        tot = self.padded_total
        return 1.0 - self.total_real_items / tot if tot else 0.0

    @property
    def imbalance(self) -> float:
        mean = float(self.lengths.mean())
        return float(self.lengths.max() / mean) if mean > 0 else 1.0

    def flat(self) -> np.ndarray:
        """Shards concatenated ``[D * L_pad, DEC_FIELDS]`` — the single-host
        execution order (runs stay contiguous; shard padding rows are inert
        valid=0 replicas, exactly like in-shard padding)."""
        return self.items.reshape(-1, DEC_FIELDS)


def pack_decode_items(
    block_ids: np.ndarray,
    *,
    num_shards: int = 1,
    block: int = 128,
    bucket: int | None = None,
    pad_multiple: int = 8,
    shard_of_kvhead: np.ndarray | None = None,
    kvhead_local: bool = False,
    bytes_per_block: float | None = None,
    phys_of_block: np.ndarray | None = None,
) -> PackedDecodeWorkList:
    """Flatten per-slot decode selections into cost-packed ragged lists.

    ``block_ids``: ``[B, Hkv, nb]`` int32 selected kv blocks per (batch
    row, kv head), -1 padding TRAILING (the engine's per-slot selection
    layout).  Each (row, head) with >= 1 selected block becomes one
    contiguous run of items; runs are assigned to shards by
    :func:`repro.core.partition.best_partition` over their true block
    counts — so each shard's grid length is proportional to its share of
    the total selected blocks, not ``Hkv x max-budget x worst-slot``.

    ``shard_of_kvhead``: ``[Hkv]`` pins every head's runs to a fixed shard
    (head-parallel islands, where the cache shard owning the head must
    execute it); packing freedom then only removes padding.  ``None`` packs
    freely across heads AND batch rows (single-device grids, replicated or
    pool-sharded caches).  ``kvhead_local`` remaps item kv-head indices to
    shard-local first-seen order (head-sharded caches — pair it with
    ``shard_of_kvhead``); the default keeps them GLOBAL.  ``bucket`` fixes
    the padded per-shard length (compile bucketing); it must be >= the
    longest shard's run total.

    ``bytes_per_block`` (§2.12 byte-true packing): the pool's REAL HBM
    bytes streamed per selected kv block (K+V codes plus amortized
    per-block scales, see ``repro.core.quant.kv_dtype_bytes``).  Weights
    become bytes instead of block counts, so the partition balances what
    the memory system actually pays.

    ``phys_of_block`` (§2.14 charge-once packing): ``[B, T]`` physical
    pool ids per LOGICAL block position of each row (-1 unmapped — the
    allocator tables, exactly what the paged executor indexes with).
    When given, a prefix-SHARED physical block is charged to a kv head's
    cost once no matter how many batch rows reference it: each run's
    weight becomes its count of first-seen physical ids within its head
    (floor 1 — every run still pays its launch/output cost).  Items are
    untouched; kv blocks stay logical.

    The dedup is a deliberate cost-model approximation when packing
    freely across shards (``shard_of_kvhead=None``): it keys seen blocks
    per kv head BEFORE the partition, but ``best_partition`` may then
    place two runs of that head on different model shards — each shard
    streams the shared block once while the weights charged it once
    globally, slightly understating those shards' true bytes.  An exact
    per-(head, shard) dedup would need the assignment the weights
    themselves produce (circular).  With ``shard_of_kvhead`` pinned
    (head-parallel islands) every run of a head lands on one shard and
    the charge is exact.
    """
    from repro.core.partition import best_partition

    ids = np.asarray(block_ids)
    assert ids.ndim == 3, f"block_ids must be [B, Hkv, nb], got {ids.shape}"
    B, hkv, nb = ids.shape
    counts = (ids >= 0).sum(axis=-1)                      # [B, Hkv]
    runs = [(b, h, int(counts[b, h]))
            for b in range(B) for h in range(hkv) if counts[b, h] > 0]
    weights = np.array([r[2] for r in runs], dtype=np.int64)
    if phys_of_block is not None:
        pob = np.asarray(phys_of_block)
        # keyed per kv head, pre-partition: exact when the head's runs
        # all land on one shard (shard_of_kvhead pinned, or 1 shard);
        # otherwise a documented understatement — see the docstring
        seen: dict[int, set[int]] = {}
        fresh_w = []
        for b, h, _ in runs:       # b-major order — deterministic dedup
            sel = ids[b, h][ids[b, h] >= 0].astype(np.int64)
            held = seen.setdefault(h, set())
            fresh = 0
            for p in pob[b, sel].tolist():
                if p >= 0 and p not in held:
                    held.add(p)
                    fresh += 1
            fresh_w.append(max(1, fresh))
        weights = np.array(fresh_w, dtype=np.int64)
    if bytes_per_block is not None:
        # byte-true weights (§2.12): scale selected-block counts by the
        # pool's real per-block HBM footprint (K+V codes + amortized
        # per-block scales).  Uniform dtype => positive scaling, so the
        # partition is unchanged; the weights read in bytes.
        weights = np.maximum(
            1, np.round(weights * float(bytes_per_block))).astype(np.int64)
    if shard_of_kvhead is None:
        asg = best_partition(weights, num_shards).device_of
    else:
        shard_of_kvhead = np.asarray(shard_of_kvhead)
        asg = np.array([int(shard_of_kvhead[h]) for _, h, _ in runs],
                       dtype=np.int64)
    per_shard: list[list[np.ndarray]] = [[] for _ in range(num_shards)]
    kv_local_map: list[dict[int, int]] = [dict() for _ in range(num_shards)]
    for (b, h, n), d in zip(runs, asg):
        d = int(d)
        if kvhead_local:
            if h not in kv_local_map[d]:
                kv_local_map[d][h] = len(kv_local_map[d])
            h_idx = kv_local_map[d][h]
        else:
            h_idx = h
        sel = np.sort(ids[b, h][ids[b, h] >= 0].astype(np.int64))
        it = np.zeros((n, DEC_FIELDS), dtype=np.int32)
        it[:, D_BATCH] = b
        it[:, D_KVHEAD] = h_idx
        it[:, D_KVBLK] = sel
        it[0, D_FIRST] = 1
        it[-1, D_LAST] = 1
        it[:, D_VALID] = 1
        per_shard[int(d)].append(it)
    dev_items = [
        np.concatenate(g, axis=0) if g else np.zeros((0, DEC_FIELDS),
                                                     np.int32)
        for g in per_shard
    ]
    lengths = np.array([len(x) for x in dev_items], dtype=np.int64)
    L_pad = int(lengths.max()) if len(lengths) else 0
    L_pad = max(pad_multiple, -(-L_pad // pad_multiple) * pad_multiple)
    if bucket is not None:
        assert bucket >= L_pad, (
            f"bucket {bucket} < packed shard length {L_pad}")
        L_pad = int(bucket)
    items = np.zeros((num_shards, L_pad, DEC_FIELDS), dtype=np.int32)
    for d, x in enumerate(dev_items):
        items[d, : len(x)] = x
        if len(x):
            # padding replicates the last real item (valid=0): the Pallas
            # out-tile index must not jump to an already-finalized tile.
            pad_row = x[-1].copy()
            pad_row[D_FIRST] = 0
            pad_row[D_LAST] = 0
            pad_row[D_VALID] = 0
            items[d, len(x):] = pad_row
    return PackedDecodeWorkList(items=items, lengths=lengths, block=block)


@dataclasses.dataclass
class PackedDecodeWorkList2D:
    """Per-(model shard, seq stripe) cost-packed decode lists for one layer
    (DESIGN.md §2.11).

    items:   ``[Dm, S, L_pad, DEC_FIELDS]`` int32 — cell (d, s) holds the
             runs assigned to model shard d whose kv blocks live on stripe
             s (kv blocks stay LOGICAL; the executor resolves them through
             the per-slot table, and stripe membership is a property of
             the PHYSICAL id, so a cell's items never reference another
             stripe's blocks).
    lengths: ``[Dm, S]`` true (unpadded) item counts per cell.
    """

    items: np.ndarray
    lengths: np.ndarray
    block: int

    @property
    def num_shards(self) -> int:
        return self.items.shape[0]

    @property
    def num_stripes(self) -> int:
        return self.items.shape[1]

    @property
    def padded_length(self) -> int:
        return self.items.shape[2]

    @property
    def total_real_items(self) -> int:
        return int(self.lengths.sum())

    @property
    def padded_total(self) -> int:
        return self.padded_length * self.num_shards * self.num_stripes

    @property
    def padding_waste(self) -> float:
        tot = self.padded_total
        return 1.0 - self.total_real_items / tot if tot else 0.0

    @property
    def imbalance(self) -> float:
        """max cell / mean cell — the 2D SPMD bubble."""
        mean = float(self.lengths.mean())
        return float(self.lengths.max() / mean) if mean > 0 else 1.0

    @property
    def model_imbalance(self) -> float:
        """Head-axis imbalance: per-model-shard totals (over stripes)."""
        m = self.lengths.sum(axis=1).astype(np.float64)
        mean = float(m.mean())
        return float(m.max() / mean) if mean > 0 else 1.0

    @property
    def stripe_imbalance(self) -> float:
        """Seq-axis imbalance: per-stripe totals (over model shards)."""
        s = self.lengths.sum(axis=0).astype(np.float64)
        mean = float(s.mean())
        return float(s.max() / mean) if mean > 0 else 1.0

    def stripe_items(self) -> np.ndarray:
        """``[S, Dm * L_pad, DEC_FIELDS]`` — the single-host execution
        layout: per stripe, all model shards' lists concatenated (the 1D
        :meth:`PackedDecodeWorkList.flat` applied within each stripe).
        The engine runs one partial pass per stripe and merges the
        ``(out, m, l)`` partials."""
        return np.swapaxes(self.items, 0, 1).reshape(
            self.num_stripes, -1, DEC_FIELDS)


def pack_decode_items_2d(
    block_ids: np.ndarray,
    stripe_of_block: np.ndarray,
    *,
    num_stripes: int,
    num_shards: int = 1,
    block: int = 128,
    bucket: int | None = None,
    pad_multiple: int = 8,
    shard_of_kvhead: np.ndarray | None = None,
    kvhead_local: bool = False,
    bytes_per_block: float | None = None,
    phys_of_block: np.ndarray | None = None,
) -> PackedDecodeWorkList2D:
    """2D (model x seq) twin of :func:`pack_decode_items`.

    ``block_ids [B, Hkv, nb]``: LOGICAL selected kv blocks per (batch row,
    kv head), -1 trailing padding.  ``stripe_of_block [B, T]``: owning seq
    stripe of each LOGICAL block position of each row (-1 for unmapped) —
    derived from the stripe-aware allocator's tables
    (``BlockAllocator.stripe_of``), since a block computes on the shard
    that physically holds it.  Each (row, head) run splits into per-stripe
    sub-runs; the run's per-stripe block counts form its weight VECTOR and
    :func:`repro.core.partition.best_partition_2d` picks its model shard
    to minimize the max (shard, stripe) CELL — the padded 2D grid.
    ``shard_of_kvhead`` pins runs to head-owning shards (islands);
    ``kvhead_local`` remaps kv-head ids shard-local, as in the 1D packer.
    Selections pointing at unmapped blocks (stripe -1) are dropped — the
    1D executor would mask them via the table's -1 anyway.
    """
    from repro.core.partition import best_partition_2d

    ids = np.asarray(block_ids)
    assert ids.ndim == 3, f"block_ids must be [B, Hkv, nb], got {ids.shape}"
    B, hkv, nb = ids.shape
    sob = np.asarray(stripe_of_block)
    assert sob.ndim == 2 and sob.shape[0] == B, \
        f"stripe_of_block must be [B, T], got {sob.shape}"
    # per-run, per-stripe sorted logical block lists
    runs: list[tuple[int, int, list[np.ndarray]]] = []   # (b, h, per-stripe)
    for b in range(B):
        for h in range(hkv):
            sel = ids[b, h][ids[b, h] >= 0].astype(np.int64)
            if not len(sel):
                continue
            stripes_of_sel = sob[b, sel]
            per_stripe = [np.sort(sel[stripes_of_sel == s])
                          for s in range(num_stripes)]
            if sum(len(p) for p in per_stripe):
                runs.append((b, h, per_stripe))
    W = np.array([[len(p) for p in per_stripe]
                  for _, _, per_stripe in runs],
                 dtype=np.int64).reshape(len(runs), num_stripes)
    if phys_of_block is not None:
        # charge-once (§2.14), per (kv head, stripe) cell: a shared
        # physical block streams once per head per stripe regardless of
        # how many rows reference it.  The stripe key is exact (stripe
        # is a property of the physical id); the head key carries the
        # same free-packing approximation as pack_decode_items — exact
        # only when shard_of_kvhead pins each head's runs to one shard
        pob = np.asarray(phys_of_block)
        seen2: dict[tuple[int, int], set[int]] = {}
        for ridx, (b, h, per_stripe) in enumerate(runs):
            for s, sel in enumerate(per_stripe):
                if not len(sel):
                    continue
                held = seen2.setdefault((h, s), set())
                fresh = 0
                for p in pob[b, np.asarray(sel, np.int64)].tolist():
                    if p >= 0 and p not in held:
                        held.add(p)
                        fresh += 1
                W[ridx, s] = max(1, fresh)
    if bytes_per_block is not None:
        # byte-true cell weights (§2.12) — see pack_decode_items
        W = np.maximum((W > 0).astype(np.int64),
                       np.round(W * float(bytes_per_block)).astype(np.int64))
    if shard_of_kvhead is None:
        asg = best_partition_2d(W, num_shards).device_of
    else:
        shard_of_kvhead = np.asarray(shard_of_kvhead)
        asg = np.array([int(shard_of_kvhead[h]) for _, h, _ in runs],
                       dtype=np.int64)
    per_cell: list[list[list[np.ndarray]]] = [
        [[] for _ in range(num_stripes)] for _ in range(num_shards)]
    kv_local_map: list[dict[int, int]] = [dict() for _ in range(num_shards)]
    for (b, h, per_stripe), d in zip(runs, asg):
        d = int(d)
        if kvhead_local:
            if h not in kv_local_map[d]:
                kv_local_map[d][h] = len(kv_local_map[d])
            h_idx = kv_local_map[d][h]
        else:
            h_idx = h
        for s, sel in enumerate(per_stripe):
            n = len(sel)
            if n == 0:
                continue
            it = np.zeros((n, DEC_FIELDS), dtype=np.int32)
            it[:, D_BATCH] = b
            it[:, D_KVHEAD] = h_idx
            it[:, D_KVBLK] = sel
            it[0, D_FIRST] = 1
            it[-1, D_LAST] = 1
            it[:, D_VALID] = 1
            per_cell[d][s].append(it)
    cell_items = [[np.concatenate(g, axis=0) if g
                   else np.zeros((0, DEC_FIELDS), np.int32)
                   for g in row] for row in per_cell]
    lengths = np.array([[len(x) for x in row] for row in cell_items],
                       dtype=np.int64).reshape(num_shards, num_stripes)
    L_pad = int(lengths.max()) if lengths.size else 0
    L_pad = max(pad_multiple, -(-L_pad // pad_multiple) * pad_multiple)
    if bucket is not None:
        assert bucket >= L_pad, (
            f"bucket {bucket} < packed cell length {L_pad}")
        L_pad = int(bucket)
    items = np.zeros((num_shards, num_stripes, L_pad, DEC_FIELDS),
                     dtype=np.int32)
    for d in range(num_shards):
        for s in range(num_stripes):
            x = cell_items[d][s]
            items[d, s, : len(x)] = x
            if len(x):
                pad_row = x[-1].copy()
                pad_row[D_FIRST] = 0
                pad_row[D_LAST] = 0
                pad_row[D_VALID] = 0
                items[d, s, len(x):] = pad_row
    return PackedDecodeWorkList2D(items=items, lengths=lengths, block=block)


def extend_packed_items(items: np.ndarray, width: int) -> np.ndarray:
    """Pad per-shard item lists ``[D, L, DEC_FIELDS]`` out to ``[D, width,
    DEC_FIELDS]`` with the replicate-last valid=0 convention (flags zeroed
    whether the trailing row was a real item or already padding).  Used to
    equalize per-layer packed lists onto one compile bucket."""
    it = np.asarray(items)
    D, L, _ = it.shape
    assert width >= L, f"cannot shrink items from {L} to {width}"
    if width == L:
        return it
    out = np.zeros((D, width, DEC_FIELDS), dtype=np.int32)
    out[:, :L] = it
    for d in range(D):
        pad_row = it[d, -1].copy()
        pad_row[D_FIRST] = 0
        pad_row[D_LAST] = 0
        pad_row[D_VALID] = 0
        out[d, L:] = pad_row
    return out


def padded_decode_items(block_ids: np.ndarray) -> np.ndarray:
    """Host twin of ``kernels.flash_decode.decode_items_from_ids``: the
    PADDED fixed-stride item table ``[B*Hkv*nb, DEC_FIELDS]`` (row
    ``(b, h, j)`` at index ``(b*Hkv + h)*nb + j``; -1 selections become
    valid=0 rows but still occupy grid steps).  This is the baseline grid
    the packed builder shrinks — benchmarks execute both through one
    executor so the packed-vs-padded latency delta is purely grid length.
    """
    ids = np.asarray(block_ids)
    B, hkv, nb = ids.shape
    flat = ids.reshape(-1).astype(np.int64)
    n = flat.shape[0]
    j = np.arange(n) % nb
    bh = np.arange(n) // nb
    items = np.zeros((n, DEC_FIELDS), dtype=np.int32)
    items[:, D_BATCH] = bh // hkv
    items[:, D_KVHEAD] = bh % hkv
    items[:, D_KVBLK] = np.maximum(flat, 0)
    items[:, D_FIRST] = (j == 0)
    items[:, D_LAST] = (j == nb - 1)
    items[:, D_VALID] = (flat >= 0)
    return items


# ---------------------------------------------------------------------------
# Cost accounting (used by roofline + benchmarks)
# ---------------------------------------------------------------------------

def worklist_flops(wl: WorkList, block: int, head_dim: int,
                   padded: bool = True) -> int:
    """MXU FLOPs of executing the work-list grid.

    Each item is two ``[block, head_dim] x [head_dim, block]``-ish matmuls
    (QK^T and AV): ``2 * 2 * block * block * head_dim`` FLOPs.  ``padded``
    counts the padded grid (what every device pays under SPMD); unpadded is
    the useful work.
    """
    per_item = 4 * block * block * head_dim
    n = wl.padded_total if padded else wl.total_real_items
    return int(per_item) * int(n)


def worklist_hbm_bytes(wl: WorkList, block: int, head_dim: int,
                       dtype_bytes: int = 2, padded: bool = True) -> int:
    """HBM->VMEM traffic: one K tile + one V tile per item (Q tile is
    reused across the contiguous run; count it on first items only)."""
    kv_tile = 2 * block * head_dim * dtype_bytes
    n = wl.padded_total if padded else wl.total_real_items
    q_tiles = int(wl.items[..., F_FIRST].sum()) if not padded else int(
        wl.items[..., F_FIRST].sum())
    q_tile = block * head_dim * dtype_bytes
    return kv_tile * int(n) + q_tile * q_tiles
