"""KV-block selection policies (TPU block-granular adaptations, DESIGN.md §2.5).

A policy answers: *given head h's block budget nb at query block qb, which kv
blocks participate?*  Two families:

**Static** (shape-only, no runtime tensors — usable in the dry-run and as the
default serving path, budgets from the offline S-HPLB plan):

- :func:`streaming_policy`      — sink blocks + most-recent blocks
  (StreamingLLM [27] at block granularity).
- :func:`strided_policy`        — sink + recent + strided middle coverage
  (a block-granular stand-in for MInference's vertical-slash pattern:
  verticals ~ strided columns, slash ~ the diagonal band).

**Dynamic** (scores from runtime Q/K, cheap O(S·D) estimators; selection =
per-(head, q_blk) top-``nb`` blocks over the scores — the MInference/Quest/
XAttention approximation step, block-granular):

- :func:`quest_block_scores`        — Quest [21]: per-block key min/max
  summaries; upper-bound score max(q·kmin, q·kmax) summed over dims.
- :func:`antidiagonal_block_scores` — XAttention [29]: sum of strided
  antidiagonal elements of each (q_blk, kv_blk) tile as the importance
  estimate.
- :func:`topk_select`               — turn scores into per-q-block selections
  under a block budget, always keeping sink + diagonal (local) blocks.

All selections are causal (kv_blk <= q_blk) and deterministic.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Static policies (host-side, numpy)
# ---------------------------------------------------------------------------

import functools


@functools.lru_cache(maxsize=4096)
def _streaming_cached(head, nb, nq, nkv, sink_blocks):
    return _streaming_impl(head, nb, nq, nkv, sink_blocks)


def streaming_policy(head: int, nb: int, nq: int, nkv: int,
                     sink_blocks: int = 1) -> list[np.ndarray]:
    return _streaming_cached(int(head), int(nb), int(nq), int(nkv),
                             int(sink_blocks))


def _streaming_impl(head: int, nb: int, nq: int, nkv: int,
                    sink_blocks: int = 1) -> list[np.ndarray]:
    """sink + recent blocks under a per-head block budget ``nb``."""
    out = []
    for qb in range(nq):
        avail = qb + 1  # causal: blocks 0..qb
        n = min(nb, avail)
        n_sink = min(sink_blocks, n)
        n_recent = n - n_sink
        sel = list(range(n_sink))
        sel += list(range(qb - n_recent + 1, qb + 1))
        out.append(np.unique(np.asarray(sel, dtype=np.int64)))
    return out


@functools.lru_cache(maxsize=4096)
def _strided_cached(head, nb, nq, nkv, sink_blocks, local_blocks):
    return _strided_impl(head, nb, nq, nkv, sink_blocks, local_blocks)


def strided_policy(head: int, nb: int, nq: int, nkv: int,
                   sink_blocks: int = 1, local_blocks: int = 2
                   ) -> list[np.ndarray]:
    return _strided_cached(int(head), int(nb), int(nq), int(nkv),
                           int(sink_blocks), int(local_blocks))


def _strided_impl(head: int, nb: int, nq: int, nkv: int,
                  sink_blocks: int = 1, local_blocks: int = 2
                  ) -> list[np.ndarray]:
    """sink + local diagonal band + strided middle blocks (vertical-ish).

    The stride phase is head-dependent so different heads cover different
    columns — the block-granular analogue of per-head vertical lines.
    """
    out = []
    for qb in range(nq):
        avail = qb + 1
        n = min(nb, avail)
        sel = set(range(min(sink_blocks, n)))
        for i in range(local_blocks):
            if len(sel) >= n:
                break
            b = qb - i
            if b >= 0:
                sel.add(b)
        middle = [b for b in range(sink_blocks, qb - local_blocks + 1)]
        if middle and len(sel) < n:
            want = n - len(sel)
            stride = max(1, len(middle) // want)
            phase = head % stride
            for b in middle[phase::stride]:
                if len(sel) >= n:
                    break
                sel.add(b)
            # fill any remainder densely from the most recent middle blocks
            for b in reversed(middle):
                if len(sel) >= n:
                    break
                sel.add(b)
        out.append(np.array(sorted(sel), dtype=np.int64))
    return out


# ---------------------------------------------------------------------------
# Dynamic score estimators (jnp, in-graph, cheap)
# ---------------------------------------------------------------------------

def quest_block_scores(q: jnp.ndarray, k: jnp.ndarray, block: int,
                       k_scales: jnp.ndarray | None = None):
    """Quest-style block upper-bound scores.

    q: [H, Sq, Dh]; k: [Hkv, Skv, Dh] -> scores [H, nq, nkv] (f32).
    Per kv block: elementwise min/max over keys; score of (q, blk) =
    sum_d max(q_d * min_d, q_d * max_d), maxed over queries in the q block.

    With a quantized cache (§2.12) pass ``k_scales [Hkv, Skv/block]`` —
    the min/max summaries are computed on DEQUANTIZED key values (scale
    is per-block positive, so min/max commute with it) keeping the upper
    bound sound w.r.t. the values attention actually sees.
    """
    hq, sq, dh = q.shape
    hkv, skv, _ = k.shape
    n_rep = hq // hkv
    pad_q = (-sq) % block
    pad_k = (-skv) % block
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
    nq = qp.shape[1] // block
    nkv = kp.shape[1] // block
    kb = kp.reshape(hkv, nkv, block, dh)
    if k_scales is not None:
        pad_b = nkv - k_scales.shape[1]
        ks = jnp.pad(k_scales.astype(jnp.float32), ((0, 0), (0, pad_b)),
                     constant_values=1.0)
        # per-block scale > 0: dequantize BEFORE the min/max reductions —
        # one [Hkv, nkv] broadcast multiply, not a full-cache copy (the
        # reshaped kb view is consumed by the reduction immediately)
        kb = kb.astype(jnp.float32) * ks[:, :, None, None]
    # padded key rows must NOT enter the min/max summaries: a zero-padded
    # trailing partial block would pull kmin/kmax toward 0 and skew that
    # block's upper bound.  Mask pads to +/-inf for the reduction, then
    # neutralize fully-padded blocks (no real keys) to 0.
    kreal = (jnp.arange(nkv * block) < skv).reshape(nkv, block)
    kmask = kreal[None, :, :, None]                      # [1, nkv, blk, 1]
    kmin = jnp.where(kmask, kb, jnp.inf).min(axis=2)     # [Hkv, nkv, dh]
    kmax = jnp.where(kmask, kb, -jnp.inf).max(axis=2)
    has_real = kreal.any(axis=1)[None, :, None]          # [1, nkv, 1]
    kmin = jnp.where(has_real, kmin, 0.0)
    kmax = jnp.where(has_real, kmax, 0.0)
    kmin = jnp.repeat(kmin, n_rep, axis=0)  # [H, nkv, dh]
    kmax = jnp.repeat(kmax, n_rep, axis=0)
    qb = qp.reshape(hq, nq, block, dh).astype(jnp.float32)
    # exact Quest bound sum_d max(q_d*kmin_d, q_d*kmax_d), decomposed as
    # relu(q)·kmax + (-relu(-q))·kmin — two einsums, no [.., nkv, dh] blowup
    ub = jnp.einsum(
        "hqbd,hkd->hqbk",
        jnp.maximum(qb, 0.0), kmax.astype(jnp.float32)) + jnp.einsum(
        "hqbd,hkd->hqbk",
        jnp.minimum(qb, 0.0), kmin.astype(jnp.float32))
    return ub.max(axis=2)  # [H, nq, nkv] max over queries in block


def antidiagonal_block_scores(q: jnp.ndarray, k: jnp.ndarray, block: int,
                              stride: int = 16):
    """XAttention-style antidiagonal importance estimate per tile.

    Sums ``block/stride`` antidiagonal strips of each (q_blk, kv_blk) logits
    tile using strided row/col subsampling — O(S^2/stride) instead of O(S^2),
    evaluated at block granularity: score[h, qb, kb] = sum of exp-logits on
    the sampled antidiagonals.
    """
    hq, sq, dh = q.shape
    hkv, skv, _ = k.shape
    n_rep = hq // hkv
    pad_q = (-sq) % block
    pad_k = (-skv) % block
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
    nq = qp.shape[1] // block
    nkv = kp.shape[1] // block
    # strided subsample inside each block: rows r = 0, stride, 2*stride, ...
    qs = qp.reshape(hq, nq, block, dh)[:, :, ::stride, :]      # [H,nq,bs,dh]
    ks = kp.reshape(hkv, nkv, block, dh)[:, :, ::stride, :]    # [Hkv,nkv,bs,dh]
    ks = jnp.repeat(ks, n_rep, axis=0)
    scale = dh ** -0.5
    s = jnp.einsum("hqad,hkbd->hqkab", qs.astype(jnp.float32),
                   ks.astype(jnp.float32)) * scale  # [H,nq,nkv,bs,bs]
    # antidiagonal sum of the subsampled tile ~ antidiagonal strips of the
    # full tile (XAttention's S(i,j) estimator).  d-th antidiagonal =
    # {(i, j) : (i + j) % bs == d}; combine via a tiny one-hot (bs <= 8).
    bs = s.shape[-1]
    ar = jnp.arange(bs)
    idx = (ar[:, None] + ar[None, :]) % bs  # [i, j] -> antidiag id
    oh = (idx[..., None] == ar[None, None, :]).astype(jnp.float32)
    sums = jnp.einsum("hqkab,abd->hqkd", s, oh)
    return sums.max(axis=-1)  # [H, nq, nkv]


def topk_select(scores: np.ndarray, budgets_blocks: np.ndarray,
                *, keep_sink: bool = True, keep_local: bool = True
                ) -> list[list[np.ndarray]]:
    """Scores [H, nq, nkv] + per-head block budgets -> selections.

    Per (head, q_blk): rank causal blocks by score desc, keep the top
    ``nb[h]`` (always including block 0 and the diagonal block when asked).
    """
    scores = np.asarray(scores)
    H, nq, nkv = scores.shape
    budgets_blocks = np.asarray(budgets_blocks, dtype=np.int64)
    out: list[list[np.ndarray]] = []
    for h in range(H):
        rows = []
        for qb in range(nq):
            avail = qb + 1
            nb = int(min(budgets_blocks[h], avail))
            forced = []
            if keep_sink:
                forced.append(0)
            if keep_local:
                forced.append(qb)
            forced = sorted(set(b for b in forced if b <= qb))
            s = scores[h, qb, :avail].copy()
            s[forced] = np.inf  # force-keep
            order = np.argsort(-s, kind="stable")[:nb]
            rows.append(np.sort(order).astype(np.int64))
        out.append(rows)
    return out


def policy_by_name(name: str):
    """Static policy factory for the engine / dry-run."""
    if name == "streaming":
        return streaming_policy
    if name == "strided":
        return strided_policy
    raise ValueError(f"unknown static policy {name!r}")
