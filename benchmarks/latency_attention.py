"""Paper Fig. 9: attention serving latency across methods and HP degrees.

Three measurements:

1. CPU wall-clock of the work-list executor at reduced scale — REAL timed
   execution of the padded per-device grids (the quantity S-HPLB shrinks);
   per method: grid length max_d L_d at D=4, plus measured seconds.

2. Roofline-DERIVED latency at paper scale (128k ctx, Llama-3.1-8B-like
   minitron-8b geometry, TPU v5e): attention FLOPs/bytes of each method's
   tile count -> seconds via the §Roofline model.  This is the CPU-only
   substitute for Fig. 9's wall-clock, and is exact w.r.t. tile counts.

The decode hot path (gather-vs-fused, packed-vs-padded grids) moved to
``benchmarks/decode_pack.py``, which owns ``BENCH_decode.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.attention.policies import streaming_policy, strided_policy
from repro.attention.worklist_jnp import worklist_attention
from repro.core.budget import maxmin_allocation, topp_allocation, uniform_allocation
from repro.core.metrics import HBM_BW, PEAK_FLOPS_BF16
from repro.core.partition import best_partition, naive_partition
from repro.core.sparsity import synthetic_head_curves
from repro.core.worklist import blocks_for_budget, build_worklist

BLOCK = 128


def _tiles_per_head(nb: np.ndarray, nq: int) -> np.ndarray:
    n = np.minimum(nb, nq)
    return nq * n - (n - 1) * n // 2


def _paper_scale_method_latency(method: str, prof, *, H=32, Hkv=8, dh=128,
                                seq=131072, k=4096, D=4) -> dict:
    # D=4 matches the paper's 4-GPU HP setting: 2 KV-group atoms per device
    # (D=8 would be degenerate — one atom per device, nothing to balance)
    """Attention-only latency (s) on D chips of the §Roofline hardware."""
    nq = seq // BLOCK
    if method == "full":
        tiles_h = np.full(H, nq * (nq + 1) // 2, np.int64)
        budgets = np.full(H, seq)
    elif method in ("topk_uniform", "streaming", "minference"):
        budgets = uniform_allocation(prof, layer=0, k=k, seq_len=seq).budgets
        tiles_h = _tiles_per_head(blocks_for_budget(budgets, BLOCK), nq)
    elif method == "xattention_topp":
        budgets = topp_allocation(prof, layer=0, p=0.9, seq_len=seq).budgets
        tiles_h = _tiles_per_head(blocks_for_budget(budgets, BLOCK), nq)
    elif method in ("s_hplb", "s_hplb_nolb"):
        budgets = maxmin_allocation(
            prof, layer=0, total=H * k, seq_len=seq).budgets
        tiles_h = _tiles_per_head(blocks_for_budget(budgets, BLOCK), nq)
    else:
        raise ValueError(method)

    # device assignment: naive contiguous vs balanced
    group = H // Hkv
    atom_w = tiles_h.reshape(Hkv, group).sum(axis=1)
    if method in ("s_hplb",):
        asg = best_partition(atom_w, D)
    else:
        asg = naive_partition(atom_w, D, mode="contiguous")
    makespan_tiles = asg.makespan          # padded grid every device pays
    flops = makespan_tiles * 4 * BLOCK * BLOCK * dh * group
    bytes_ = makespan_tiles * 2 * BLOCK * dh * 2 * group
    t = max(flops / PEAK_FLOPS_BF16, bytes_ / HBM_BW)
    return {"makespan_tiles": int(makespan_tiles),
            "total_tiles": int(tiles_h.sum()),
            "latency_s": float(t),
            "imbalance": float(asg.imbalance)}


def _time(f, *args, iters=10):
    f(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(*args).block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(out_dir: str, quick: bool = False) -> list[tuple[str, float]]:
    rows: list[tuple[str, float]] = []
    prof = synthetic_head_curves(1, 32)

    # ---- derived, paper scale (128k) ------------------------------------
    derived = {}
    for m in ("full", "topk_uniform", "xattention_topp", "s_hplb_nolb",
              "s_hplb"):
        derived[m] = _paper_scale_method_latency(m, prof)
        rows.append((f"derived128k_{m}_latency_s",
                     derived[m]["latency_s"]))
    rows.append(("derived128k_speedup_vs_full",
                 derived["full"]["latency_s"]
                 / derived["s_hplb"]["latency_s"]))
    rows.append(("derived128k_speedup_vs_topp",
                 derived["xattention_topp"]["latency_s"]
                 / derived["s_hplb"]["latency_s"]))
    rows.append(("derived128k_lb_gain",
                 derived["s_hplb_nolb"]["latency_s"]
                 / derived["s_hplb"]["latency_s"]))

    # ---- measured, reduced scale ----------------------------------------
    H, Hkv, S, dh, D = 8, 4, (2048 if not quick else 1024), 64, 4
    seq = S
    nq = seq // BLOCK
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (H, seq, dh), jnp.float32)
    kk = jax.random.normal(ks[1], (Hkv, seq, dh), jnp.float32)
    vv = jax.random.normal(ks[2], (Hkv, seq, dh), jnp.float32)
    prof8 = synthetic_head_curves(1, H)
    budgets = maxmin_allocation(
        prof8, layer=0, total=H * seq // 8, seq_len=seq).budgets
    nb = blocks_for_budget(budgets, BLOCK)
    sels = [strided_policy(h, int(nb[h]), nq, nq) for h in range(H)]
    measured = {}
    for mode in ("naive", "hplb"):
        # per-HEAD atoms (kv replicated in the reduced-scale runner):
        # 8 heads over 4 devices = 2 atoms/device, real balancing freedom
        head_w = _tiles_per_head(nb, nq)
        asg = (naive_partition(head_w, D, mode="contiguous")
               if mode == "naive" else best_partition(head_w, D))
        dev_of_head = asg.device_of
        wl = build_worklist(sels, dev_of_head, D, nq, nq, BLOCK,
                            kv_head_of_head=np.arange(H) // (H // Hkv),
                            kv_local=False)
        # execute each device's padded list sequentially, timing the max
        run_one = jax.jit(lambda q, k, v, it: worklist_attention(
            q, k, v, it, block_q=BLOCK, block_kv=BLOCK))
        times = []
        for d in range(D):
            it = jnp.asarray(wl.items[d])
            run_one(q, kk, vv, it).block_until_ready()  # compile+warm
            t0 = time.perf_counter()
            run_one(q, kk, vv, it).block_until_ready()
            times.append(time.perf_counter() - t0)
        measured[mode] = {"max_device_s": max(times),
                          "sum_device_s": sum(times),
                          "padded_len": wl.padded_length,
                          "imbalance": wl.imbalance}
    rows.append(("measured_naive_max_device_s",
                 measured["naive"]["max_device_s"]))
    rows.append(("measured_hplb_max_device_s",
                 measured["hplb"]["max_device_s"]))
    rows.append(("measured_lb_speedup",
                 measured["naive"]["max_device_s"]
                 / measured["hplb"]["max_device_s"]))
    rows.append(("measured_padded_grid_ratio",
                 measured["naive"]["padded_len"]
                 / measured["hplb"]["padded_len"]))

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "latency_attention.json"), "w") as f:
        json.dump({"derived_128k": derived, "measured": measured}, f,
                  indent=1)
    return rows
