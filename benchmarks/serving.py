"""Serving-loop benchmark: chunked vs monolithic prefill under a mixed
workload — the paper's tail-latency regime.

Scenario: 4 short requests are decoding when 1 long-context prompt
arrives.  Under monolithic prefill the arrival stalls every decoder for the
whole prompt's prefill latency (the p99 inter-token spike S-HPLB's balanced
attention cannot fix from the kernel side); under chunked prefill each tick
runs one block-aligned chunk plus the full decode batch, so the stall is
bounded by one chunk.

Reports TTFT and inter-token latency (p50/p99, median over repetitions —
CI machines are noisy and one contended rep should not set the record) for
both modes, verifies the generated tokens are IDENTICAL (greedy; chunk
work-lists are slices of the monolithic ones), and writes
``BENCH_serving.json``.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.sparsity import synthetic_head_curves
from repro.models.transformer import TransformerConfig, init_params
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.serving.scheduler import Request

CFG = TransformerConfig(
    name="serving-bench", num_layers=2, d_model=128, num_heads=8,
    num_kv_heads=4, d_ff=256, vocab_size=512, layer_loop="unroll",
    dtype=jnp.float32)

NUM_SHORT = 4
SHORT_LEN = 64
ARRIVAL_TICK = 6  # the long prompt arrives once the shorts are decoding


def _drive(eng: Engine, shorts, long, sp_short, sp_long):
    """Manual tick loop with a mid-stream long-prompt arrival."""
    batcher = eng.make_batcher()
    pf, df = eng.step_fns(sp_short)  # greedy for every request here
    for i, p in enumerate(shorts):
        batcher.submit(Request(rid=i, prompt=np.asarray(p, np.int32),
                               sampling=sp_short))
    done, ticks, submitted_long = [], 0, False
    while batcher.busy or not submitted_long:
        if ticks == ARRIVAL_TICK:
            batcher.submit(Request(rid=NUM_SHORT,
                                   prompt=np.asarray(long, np.int32),
                                   sampling=sp_long))
            submitted_long = True
        done.extend(batcher.tick(pf, df))
        ticks += 1
        if ticks > 100_000:
            raise RuntimeError("serving benchmark did not drain")
    return {r.rid: r for r in done}, batcher.stats


def _metrics(by_rid):
    itl = np.concatenate([np.asarray(by_rid[i].itl)
                          for i in range(NUM_SHORT)]) * 1e3
    return {
        "itl_p50_ms": float(np.percentile(itl, 50)),
        "itl_p99_ms": float(np.percentile(itl, 99)),
        "ttft_long_ms": float(by_rid[NUM_SHORT].ttft * 1e3),
    }


def run(out_dir: str, quick: bool = False):
    # quick keeps the FULL geometry (the 8:1 prompt:chunk ratio is what
    # puts the monolithic stall structurally above scheduler noise) and
    # trims repetitions/decode lengths instead.
    long_len = 2048
    chunk = 256
    max_seq = 2560
    reps = 3 if quick else 5
    sp_short = SamplingParams(max_tokens=32 if quick else 56)
    sp_long = SamplingParams(max_tokens=8)
    rng = np.random.default_rng(0)
    shorts = [rng.integers(0, CFG.vocab_size, size=(SHORT_LEN,))
              for _ in range(NUM_SHORT)]
    long = rng.integers(0, CFG.vocab_size, size=(long_len,))
    params = init_params(jax.random.PRNGKey(0), CFG)
    profile = synthetic_head_curves(CFG.num_layers, CFG.num_heads)

    modes = ("monolithic", "chunked")
    engines = {}
    for mode in modes:
        engines[mode] = Engine(
            CFG, params,
            EngineConfig(attention="sparse", budget_per_head=256,
                         max_seq_len=max_seq, num_slots=NUM_SHORT + 1,
                         prefill_mode=mode, prefill_chunk_tokens=chunk),
            profile=profile)
        _drive(engines[mode], shorts, long, sp_short, sp_long)  # warm/compile
    # reps INTERLEAVE the two modes so a burst of machine contention (CI
    # neighbors) lands on both sides instead of poisoning one mode's phase
    rep_metrics = {m: [] for m in modes}
    chunks_of, gens = {}, {}
    for _ in range(reps):
        for mode in modes:
            t0 = time.monotonic()
            by_rid, stats = _drive(engines[mode], shorts, long,
                                   sp_short, sp_long)
            m = _metrics(by_rid)
            m["makespan_ms"] = (time.monotonic() - t0) * 1e3
            rep_metrics[mode].append(m)
            chunks_of[mode] = stats.prefill_chunks
            gens[mode] = {rid: r.generated for rid, r in by_rid.items()}
    results = {}
    for mode in modes:
        med = {k: float(np.median([r[k] for r in rep_metrics[mode]]))
               for k in rep_metrics[mode][0]}
        med["prefill_chunks"] = chunks_of[mode]
        med["reps"] = rep_metrics[mode]
        results[mode] = med

    identical = gens["chunked"] == gens["monolithic"]
    speedup = (results["monolithic"]["itl_p99_ms"]
               / results["chunked"]["itl_p99_ms"])
    payload = {
        "config": {"long_len": long_len, "chunk_tokens": chunk,
                   "num_short": NUM_SHORT, "short_len": SHORT_LEN,
                   "max_seq_len": max_seq, "reps": reps, "quick": quick},
        "modes": results,
        "tokens_identical": identical,
        "itl_p99_speedup": speedup,
    }
    with open(os.path.join(out_dir, "BENCH_serving.json"), "w") as f:
        json.dump(payload, f, indent=2)

    rows = [("tokens_identical", float(identical)),
            ("itl_p99_speedup", speedup)]
    for mode, m in results.items():
        for k in ("itl_p50_ms", "itl_p99_ms", "ttft_long_ms"):
            rows.append((f"{k}_{mode}", m[k]))
    return rows
