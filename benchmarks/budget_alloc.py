"""Paper Fig. 7: the iterative max-min budget-shifting trace.

Records min/mean recovery per transfer iteration of the paper's greedy,
compares the converged point against the uniform baseline and the exact
water-filling optimum, and validates the two stop conditions."""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.budget import (
    maxmin_allocation,
    uniform_allocation,
    waterfill_allocation,
)
from repro.core.sparsity import synthetic_head_curves


def run(out_dir: str, quick: bool = False) -> list[tuple[str, float]]:
    H, seq, k = 32, 32768, 4096
    prof = synthetic_head_curves(1, H)
    total = H * k

    uni = uniform_allocation(prof, layer=0, k=k, seq_len=seq)
    mm = maxmin_allocation(prof, layer=0, total=total, seq_len=seq)
    wf = waterfill_allocation(prof, layer=0, total=total, seq_len=seq)

    rows = [
        ("uniform_min_recovery", uni.min_recovery),
        ("uniform_mean_recovery", uni.mean_recovery),
        ("maxmin_min_recovery", mm.min_recovery),
        ("maxmin_mean_recovery", mm.mean_recovery),
        ("waterfill_min_recovery", wf.min_recovery),
        ("maxmin_iterations", float(mm.iterations)),
        ("maxmin_vs_uniform_min_gain", mm.min_recovery - uni.min_recovery),
        ("maxmin_gap_to_oracle", wf.min_recovery - mm.min_recovery),
        ("budget_spread_max_over_min",
         float(mm.budgets.max() / mm.budgets.min())),
    ]

    # iteration trace (re-run with increasing iteration caps)
    trace = []
    for it in [0, 1, 2, 4, 8, 16, 32, 64, 128, 256]:
        a = maxmin_allocation(prof, layer=0, total=total, seq_len=seq,
                              max_iters=max(it, 1) if it else 1)
        trace.append({"iters": it, "min": a.min_recovery,
                      "mean": a.mean_recovery})

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "budget_alloc.json"), "w") as f:
        json.dump({"rows": dict(rows), "trace": trace,
                   "budgets": mm.budgets.tolist()}, f, indent=1)
    return rows
