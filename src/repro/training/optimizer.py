"""AdamW + schedules, pure JAX (no optax dependency).

Optimizer state mirrors the param tree (m, v) plus a scalar step count; all
update math is elementwise, so the state inherits the params' sharding under
pjit (first/second moments live on the same shards as their weights).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.utils.trees import global_norm


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"  # "cosine" | "constant"


def init_opt_state(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                          params),
        "v": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                          params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(step, cfg: AdamWConfig):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_update(params, grads, opt_state, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    new = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([n[0] for n in new])
    new_m = tdef.unflatten([n[1] for n in new])
    new_v = tdef.unflatten([n[2] for n in new])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr}
