"""Production serving launcher (single-host path; production mesh via the
dry-run on this container).

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --smoke \
        [--attention sparse|dense] [--budget 512] [--requests 8]
"""
import argparse
import time

import numpy as np
import jax

from repro.configs import ARCHS
from repro.core.sparsity import synthetic_head_curves
from repro.launch.steps import _init_fn_for
from repro.serving import Engine, EngineConfig, SamplingParams
from repro.utils.logging import get_logger

log = get_logger("serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attention", default="sparse",
                    choices=["sparse", "dense"])
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--max-seq", type=int, default=1024)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-tokens", type=int, default=16)
    # adaptive replanning (plan epochs, DESIGN.md §2.9)
    ap.add_argument("--telemetry-every", type=int, default=0,
                    help="probe realized per-head recovery every N decode "
                         "ticks (0 = telemetry off)")
    ap.add_argument("--replan-every", type=int, default=None,
                    help="force a plan-epoch replan every N decode ticks")
    ap.add_argument("--drift-threshold", type=float, default=None,
                    help="replan when online-vs-offline profile drift "
                         "reaches this value (needs --telemetry-every)")
    # overload robustness (DESIGN.md §2.10)
    ap.add_argument("--admission", default="fifo", choices=["fifo", "slo"],
                    help="admission policy: class-blind arrival order "
                         "(fifo) or SLO-aware class scheduling with "
                         "cost-model deferral and deadline shedding (slo)")
    ap.add_argument("--preemption", action="store_true",
                    help="allow preempting strictly-lower-priority decodes "
                         "(KV blocks swap to a pinned-host tier; resume is "
                         "bitwise-identical)")
    ap.add_argument("--host-blocks", type=int, default=None,
                    help="host swap-tier capacity in KV blocks "
                         "(default: unbounded)")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="device KV pool size in blocks (default: "
                         "slots * max_seq / block)")
    # quantized KV-cache block pool (DESIGN.md §2.12)
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "int8", "fp8"],
                    help="KV pool storage dtype: bf16 (exact, default) or "
                         "int8/fp8 codes with per-(block, kv-head) scales "
                         "dequantized inside the flash-decode kernels "
                         "(~2x/4x resident tokens at equal HBM)")
    # sequence-parallel long context (DESIGN.md §2.11)
    ap.add_argument("--seq-shards", type=int, default=1,
                    help="stripe the paged KV pool across N seq shards "
                         "(2D head x sequence layout; 1 = head-parallel "
                         "only). Greedy outputs are identical at any "
                         "value.")
    # fault injection + self-healing (DESIGN.md §2.13)
    ap.add_argument("--fault-plan", default=None,
                    help="fault-injection plan: a JSON file path, an "
                         "inline JSON string, or 'random:SEED:RATE' for a "
                         "seeded Bernoulli schedule over all seams")
    ap.add_argument("--audit-every", type=int, default=0,
                    help="run the allocator/pool invariant auditor every "
                         "N decode ticks and at swap/replan boundaries "
                         "(0 = audits off)")
    ap.add_argument("--swap-retries", type=int, default=3,
                    help="bounded retries for host swap transfers before "
                         "falling back to discard-and-requeue")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for crash-consistent serving "
                         "snapshots (written at replan-safe boundaries)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot every N decode ticks into "
                         "--checkpoint-dir (0 = checkpoints off)")
    # radix-tree prefix cache (DESIGN.md §2.14)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share identical prompt-prefix KV blocks by "
                         "refcount through a radix tree (paged layout; "
                         "greedy outputs are identical either way). The "
                         "synthetic workload switches to an 80%%-shared "
                         "agent pattern so hits actually occur.")
    args = ap.parse_args()
    if args.drift_threshold is not None and args.telemetry_every <= 0:
        ap.error("--drift-threshold needs --telemetry-every > 0")
    if args.seq_shards < 1:
        ap.error("--seq-shards must be >= 1")
    if args.checkpoint_every > 0 and not args.checkpoint_dir:
        ap.error("--checkpoint-every needs --checkpoint-dir")

    injector = None
    if args.fault_plan:
        import os
        from repro.serving import FaultInjector, FaultPlan
        if args.fault_plan.startswith("random:"):
            _, seed, rate = args.fault_plan.split(":")
            plan = FaultPlan.random(int(seed), float(rate))
        elif os.path.exists(args.fault_plan):
            plan = FaultPlan.load(args.fault_plan)
        else:
            plan = FaultPlan.from_json(args.fault_plan)
        injector = FaultInjector(plan)
        log.info("fault injection armed: %d specs (seed %s)",
                 len(plan.specs), plan.seed)

    spec = ARCHS[args.arch]
    if spec.module not in ("transformer",):
        raise SystemExit(
            f"serve launcher currently drives transformer-family archs; "
            f"{args.arch} is {spec.module}")
    cfg = spec.smoke if args.smoke else spec.full
    init = _init_fn_for(type(spec)(**{**spec.__dict__, "full": cfg}))
    params = init(jax.random.PRNGKey(0))

    profile = None
    if args.attention == "sparse":
        profile = synthetic_head_curves(cfg.num_layers, cfg.num_heads)
    eng = Engine(cfg, params, EngineConfig(
        attention=args.attention, budget_per_head=args.budget,
        max_seq_len=args.max_seq, num_slots=args.slots,
        num_kv_blocks=args.kv_blocks,
        telemetry_every=args.telemetry_every,
        replan_every=args.replan_every,
        drift_threshold=args.drift_threshold,
        admission=args.admission, preemption=args.preemption,
        host_swap_blocks=args.host_blocks,
        seq_shards=args.seq_shards,
        kv_dtype=args.kv_dtype,
        audit_every=args.audit_every,
        swap_retries=args.swap_retries,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        prefix_cache=args.prefix_cache), profile=profile,
        injector=injector)

    rng = np.random.default_rng(0)
    if args.prefix_cache:
        # agent workload: 80% of requests continue one shared system
        # prompt, the rest are unique — the shape prefix sharing serves
        shared = rng.integers(0, min(cfg.vocab_size, 256), size=(256,))
        prompts = []
        for i in range(args.requests):
            tail = rng.integers(0, min(cfg.vocab_size, 256),
                                size=(int(rng.integers(16, 48)),))
            prompts.append(np.concatenate([shared, tail]) if i % 5 else
                           rng.integers(0, min(cfg.vocab_size, 256),
                                        size=(int(rng.integers(32, 128)),)))
    else:
        prompts = [rng.integers(0, min(cfg.vocab_size, 256),
                                size=(int(rng.integers(32, 128)),))
                   for _ in range(args.requests)]
    classes = ("interactive", "standard", "batch")
    priorities = [classes[i % len(classes)] for i in range(len(prompts))]
    t0 = time.time()
    done = eng.serve(prompts, SamplingParams(max_tokens=args.max_tokens),
                     priorities=priorities)
    dt = time.time() - t0
    n_tok = sum(len(r.generated) for r in done)
    log.info("served %d requests, %d tokens in %.1fs (%.1f tok/s)",
             len(done), n_tok, dt, n_tok / dt)
    bs = eng.decode_bubble_stats
    if args.prefix_cache and bs.get("prefix"):
        ps = bs["prefix"]
        log.info("prefix cache: %d/%d lookups hit (%d tokens mapped for "
                 "free), %d blocks in tree, %d evicted",
                 ps["hits"], ps["lookups"], ps["hit_tokens"],
                 ps["nodes"], ps["evicted_blocks"])
    n_failed = sum(1 for r in done if r.failed)
    if injector is not None or args.audit_every or n_failed:
        fs = bs["faults"]
        log.info("fault layer: %d injected events, %d failed requests, "
                 "%d sentinel trips, %d swap retries (%d recovered / %d "
                 "gave up), %d clean audits, %d replan rollbacks, %d "
                 "checkpoints", bs["injected_events"], n_failed,
                 fs["sentinel_trips"], fs["swap_retries"],
                 fs["swap_recoveries"], fs["swap_giveups"], fs["audits"],
                 fs["replan_rollbacks"], fs["checkpoints"])
        for r in done:
            if r.failed:
                log.info("  rid %d failed: %s", r.rid, r.fail_reason)
    if args.seq_shards > 1:
        log.info("2D decode: head imbalance %.3f, stripe imbalance %.3f, "
                 "%d seq-merge collectives", bs["mean_head_imbalance"],
                 bs["mean_stripe_imbalance"], bs["merge_collectives"])
    if bs["swap"]["swapped_out"] or args.preemption:
        log.info("preemption: %d swapped out / %d back in (%d blocks, "
                 "%.1f KiB to host)", bs["swap"]["swapped_out"],
                 bs["swap"]["swapped_in"], bs["swap"]["blocks_out"],
                 bs["swap"]["bytes_out"] / 1024)
    if eng.plan is not None:
        from repro.core.planner import plan_summary
        s = plan_summary(eng.plan)
        log.info("plan imbalance %.3f (naive %.3f), grid saving %.1f%%",
                 s["mean_imbalance_plan"], s["mean_imbalance_naive"],
                 100 * s["padded_grid_saving"])
        bs = eng.decode_bubble_stats
        if bs["realized_recovery"] is not None:
            log.info("epoch %d after %d replan(s); realized recovery %.3f"
                     "%s", eng.epoch, eng.replans, bs["realized_recovery"],
                     (f", drift {bs['drift']['drift']:.3f}"
                      if bs["drift"] else ""))


if __name__ == "__main__":
    main()
