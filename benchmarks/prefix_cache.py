"""Radix-tree prefix cache benchmark (DESIGN.md §2.14) — ``BENCH_prefix.json``.

A shared-prefix serving workload (the agent / few-shot pattern: one long
system prompt, many short continuations) swept over the shared fraction:
at each hit rate a fresh engine serves the same request count one at a
time, so each request's TTFT is pure prefill work, not queueing.

Measurements, one per §2.14 acceptance claim:

1. ``hit_ttft_ratio`` — mean TTFT of cache-HIT requests at 90% shared vs
   the all-cold baseline.  A hit maps the shared blocks by identity and
   prefills only the divergent tail, so the ratio tracks
   ``tail / (prefix + tail)`` plus scheduler overhead.
   Acceptance: <= 0.15 at a 1024-token prefix with 64-token tails.

2. ``tokens_per_s`` at each hit rate — admitted throughput (prefill +
   decoded tokens over the serve makespan).  Skipped prefill work turns
   directly into throughput, so the 90% point must beat the cold point.

3. ``parity`` — greedy tokens of a cache-ON serve equal the cache-OFF
   serve of the same prompts (the load-bearing bitwise claim; the full
   matrix lives in ``tests/test_prefix_cache.py``).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np
import jax

from repro.core.sparsity import synthetic_head_curves
from repro.models.transformer import TransformerConfig, init_params
from repro.serving import Engine, EngineConfig, SamplingParams

CFG = TransformerConfig(num_layers=2, d_model=128, num_heads=8,
                        num_kv_heads=4, d_ff=256, vocab_size=512,
                        layer_loop="unroll")
BLOCK = 64
PREFIX_TOKENS = 1024
TAIL_TOKENS = 64
MAX_SEQ = 2048
HIT_RATES = (0.0, 0.5, 0.9)


def _engine(params, profile, on: bool) -> Engine:
    return Engine(CFG, params, EngineConfig(
        attention="sparse", budget_per_head=MAX_SEQ, block=BLOCK,
        floor=BLOCK, max_seq_len=MAX_SEQ, num_slots=4,
        prefill_mode="chunked", prefill_chunk_tokens=256,
        prefix_cache=on), profile=profile)


def _workload(rng, n_requests: int, hit_rate: float, shared: np.ndarray):
    """[(prompt, is_hit)] — ``hit_rate`` of the requests continue the
    shared prefix; the rest are fully unique prompts of equal length."""
    n_hit = int(round(n_requests * hit_rate))
    reqs = []
    for i in range(n_requests):
        tail = rng.integers(0, CFG.vocab_size, size=(TAIL_TOKENS,))
        if i < n_hit:
            reqs.append((np.concatenate([shared, tail]), True))
        else:
            uniq = rng.integers(0, CFG.vocab_size,
                                size=(PREFIX_TOKENS + TAIL_TOKENS,))
            reqs.append((uniq, False))
    rng.shuffle(reqs)
    return reqs


def _serve_one_by_one(eng, reqs, sp):
    """Sequential serves: TTFT is prefill latency, not queue delay."""
    ttfts, toks, t0 = [], 0, time.monotonic()
    for prompt, is_hit in reqs:
        r = eng.serve([prompt], sp)[0]
        assert r.ttft is not None
        ttfts.append((r.ttft, is_hit))
        toks += len(prompt) + len(r.generated)
    return ttfts, toks, time.monotonic() - t0


def run(out_dir: str, quick: bool = False):
    n_requests = 10 if quick else 20
    sp = SamplingParams(max_tokens=4)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, CFG.vocab_size, size=(PREFIX_TOKENS,))
    params = init_params(jax.random.PRNGKey(0), CFG)
    profile = synthetic_head_curves(CFG.num_layers, CFG.num_heads)

    results = {}
    for rate in HIT_RATES:
        eng = _engine(params, profile, on=True)
        # warm: compiles every chunk program AND seeds the radix tree with
        # the shared prefix (the donor serve is not measured)
        eng.serve([np.concatenate(
            [shared, rng.integers(0, CFG.vocab_size,
                                  size=(TAIL_TOKENS,))])], sp)
        reqs = _workload(np.random.default_rng(1), n_requests, rate, shared)
        ttfts, toks, wall = _serve_one_by_one(eng, reqs, sp)
        st = eng.prefix.stats
        results[f"{rate:.2f}"] = {
            "ttft_mean_ms": float(np.mean([t for t, _ in ttfts])) * 1e3,
            "ttft_hit_mean_ms": (float(np.mean(
                [t for t, h in ttfts if h])) * 1e3
                if any(h for _, h in ttfts) else None),
            "ttft_cold_mean_ms": float(np.mean(
                [t for t, h in ttfts if not h])) * 1e3
                if any(not h for _, h in ttfts) else None,
            "tokens_per_s": toks / wall,
            "requests_per_s": n_requests / wall,
            "prefix_hits": st["hits"],
            "prefix_hit_tokens": st["hit_tokens"],
        }

    cold = results["0.00"]["ttft_mean_ms"]
    hot = results["0.90"]["ttft_hit_mean_ms"]
    hit_ratio = hot / cold
    speedup = results["0.90"]["tokens_per_s"] / results["0.00"]["tokens_per_s"]

    # bitwise parity spot-check: same prompts, cache on vs off
    par_prompts = [np.concatenate(
        [shared, rng.integers(0, CFG.vocab_size, size=(TAIL_TOKENS,))])
        for _ in range(3)]
    on = _engine(params, profile, on=True)
    off = _engine(params, profile, on=False)
    got_on = {r.rid: list(r.generated) for r in on.serve(par_prompts, sp)}
    got_off = {r.rid: list(r.generated) for r in off.serve(par_prompts, sp)}
    parity = got_on == got_off
    assert parity, "prefix-cache serve diverged from the cache-off serve"
    assert hit_ratio <= 0.15, \
        f"hit TTFT ratio {hit_ratio:.3f} exceeds the 0.15 acceptance bound"

    payload = {
        "config": {
            "prefix_tokens": PREFIX_TOKENS, "tail_tokens": TAIL_TOKENS,
            "block": BLOCK, "n_requests": n_requests,
            "hit_rates": list(HIT_RATES), "quick": quick,
        },
        "by_hit_rate": results,
        "hit_ttft_ratio": hit_ratio,
        "throughput_speedup_90": speedup,
        "parity": parity,
    }
    with open(os.path.join(out_dir, "BENCH_prefix.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return [
        ("ttft_cold_ms", results["0.00"]["ttft_mean_ms"]),
        ("ttft_hit_ms_at_90", hot),
        ("hit_ttft_ratio", hit_ratio),
        ("tokens_per_s_at_0", results["0.00"]["tokens_per_s"]),
        ("tokens_per_s_at_50", results["0.50"]["tokens_per_s"]),
        ("tokens_per_s_at_90", results["0.90"]["tokens_per_s"]),
        ("throughput_speedup_90", speedup),
        ("parity", float(parity)),
    ]


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="quick sizes (CI prefix-cache smoke)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "artifacts", "bench"))
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    for metric, value in run(args.out, quick=args.smoke):
        print(f"prefix_cache,{metric},{value:.6g}")
