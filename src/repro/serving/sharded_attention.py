"""shard_map S-HPLB attention islands (DESIGN.md §2.4).

Per-device DISTINCT work is impossible under plain GSPMD jit (one program,
uniform shapes); the S-HPLB execution model therefore runs inside shard_map
islands over the ``model`` axis:

- :func:`hplb_prefill_attention` — each model-shard executes ITS OWN
  work-list (the per-device lists built by the HPLB planner; lengths
  equalized to max_d L_d, which the partitioner minimizes).  Heads are
  already permuted into slot order in the weights, so shard d's q/k/v slices
  are exactly its assigned heads.

- :func:`flash_decode_attention` — decode against a SEQUENCE-sharded KV
  cache (the long-context layout): each shard computes a partial online
  softmax over its local kv blocks — budgeted via per-shard block-id lists —
  and the partials merge with the flash-decoding (acc, m, l) combine over
  the mesh axes.  S-HPLB balances the per-shard block counts.

Both islands use the pure-jnp work-list executors on CPU and the Pallas
kernels (kernels.ops) on TPU.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.compat import shard_map

from repro.attention.worklist_jnp import worklist_attention
from repro.kernels import ops

NEG_INF = -1e30


def _batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def hplb_prefill_attention(mesh, *, block_q=128, block_kv=128,
                           kv_sharded: bool = True):
    """Build the shard_map prefill attention: (q, k, v, items) -> o.

    q [B, H, S, D] sharded (batch, model, -, -); items
    [n_model, L, Lpad, 7] sharded on axis 0 — inside the island each shard
    sees its own [1, L, Lpad, 7] list.  Returns a callable taking the LAYER
    index to slice items (so one shard_map signature serves every layer).

    ``kv_sharded``: kv_group mode (kv heads sharded with their q heads,
    item kv indices device-local).  False = kv_replication mode (fewer kv
    heads than shards, e.g. minitron 8 kv over 16): k/v replicate over the
    model axis (shard_map inserts the all-gather) and item kv indices are
    GLOBAL.
    """
    ba = _batch_axes(mesh)
    bspec = ba[0] if len(ba) == 1 else (ba if ba else None)
    kv_spec = "model" if kv_sharded else None

    def attend(l, q, k, v, items):
        def island(q_l, k_l, v_l, items_l):
            # q_l [B_l, H_loc, S, D]; items_l [1, L, Lpad, 7]
            it = items_l[0, l]
            fn = functools.partial(
                worklist_attention, items=it,
                block_q=block_q, block_kv=block_kv)
            return jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv))(q_l, k_l, v_l)

        return shard_map(
            island, mesh=mesh,
            in_specs=(P(bspec, "model", None, None),
                      P(bspec, kv_spec, None, None),
                      P(bspec, kv_spec, None, None),
                      P("model", None, None, None)),
            out_specs=P(bspec, "model", None, None),
            check_vma=False,
        )(q, k, v, items)

    return attend


def hplb_prefill_attention_rows(mesh, *, block_q=128, block_kv=128):
    """Row-mode shard_map prefill: (head, q_blk) rows partitioned over the
    model axis (archs whose head count does not divide the mesh — see
    ``core.worklist.build_row_worklist``).  q/k/v replicated inside the
    island; disjoint output tiles combine via psum over 'model'."""
    ba = _batch_axes(mesh)
    bspec = ba[0] if len(ba) == 1 else (ba if ba else None)

    def attend(l, q, k, v, items):
        def island(q_l, k_l, v_l, items_l):
            it = items_l[0, l]
            fn = functools.partial(
                worklist_attention, items=it,
                block_q=block_q, block_kv=block_kv)
            o = jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv))(q_l, k_l, v_l)
            return jax.lax.psum(o, "model")

        return shard_map(
            island, mesh=mesh,
            in_specs=(P(bspec, None, None, None),
                      P(bspec, None, None, None),
                      P(bspec, None, None, None),
                      P("model", None, None, None)),
            out_specs=P(bspec, None, None, None),
            check_vma=False,
        )(q, k, v, items)

    return attend


def hplb_decode_attention_packed(mesh, *, block_kv=128):
    """Head-parallel cost-packed decode island (DESIGN.md §2.8): each
    model shard executes ITS OWN packed decode worklist against its head
    shard of the slot cache — the decode twin of
    :func:`hplb_prefill_attention`.

    q ``[B, H, 1, D]`` sharded on heads over 'model'; kc/vc
    ``[B, Hkv, Smax, D]`` sharded on kv heads; items
    ``[n_model, L_pad, DEC_FIELDS]`` sharded on axis 0 — built by
    ``core.worklist.pack_decode_items(..., shard_of_kvhead=...,
    kvhead_local=True)`` so every item's kv head indexes the LOCAL cache
    shard.  Lists are equalized to ``max_d L_d``, which the cost packing
    minimizes; heads are disjoint across shards so no cross-shard merge is
    needed.  ``pos [B]`` replicates.
    """
    ba = _batch_axes(mesh)
    bspec = ba[0] if len(ba) == 1 else (ba if ba else None)

    def attend(q, kc, vc, items, pos, k_scales=None, v_scales=None):
        B = q.shape[0]
        pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        qz = k_scales is not None

        if qz:
            # quantized cache (§2.12): dequant scales [B, Hkv, S/blk]
            # shard on kv heads WITH their cache shard — dequantization
            # stays entirely island-local, no extra collective
            def island(q_l, kc_l, vc_l, items_l, pos_l, ks_l, vs_l):
                return ops.flash_decode_packed(
                    q_l, kc_l, vc_l, items_l[0], pos_l, block_kv=block_kv,
                    k_scales=ks_l, v_scales=vs_l)

            return shard_map(
                island, mesh=mesh,
                in_specs=(P(bspec, "model", None, None),
                          P(bspec, "model", None, None),
                          P(bspec, "model", None, None),
                          P("model", None, None),
                          P(bspec),
                          P(bspec, "model", None),
                          P(bspec, "model", None)),
                out_specs=P(bspec, "model", None, None),
                check_vma=False,
            )(q, kc, vc, items, pos_b, k_scales, v_scales)

        def island(q_l, kc_l, vc_l, items_l, pos_l):
            # q_l [B_l, H_loc, 1, D]; kc_l [B_l, Hkv_loc, S, D];
            # items_l [1, L_pad, DEC_FIELDS] — this shard's packed list
            return ops.flash_decode_packed(
                q_l, kc_l, vc_l, items_l[0], pos_l, block_kv=block_kv)

        return shard_map(
            island, mesh=mesh,
            in_specs=(P(bspec, "model", None, None),
                      P(bspec, "model", None, None),
                      P(bspec, "model", None, None),
                      P("model", None, None),
                      P(bspec)),
            out_specs=P(bspec, "model", None, None),
            check_vma=False,
        )(q, kc, vc, items, pos_b)

    return attend


def hplb_repermute_kv_cache(mesh, *, axis="model"):
    """Plan-epoch swap island (DESIGN.md §2.9): re-permute the kv-head
    axis of a HEAD-SHARDED resident cache across model shards.

    ``cache``: any 6-d layout with kv heads on axis 3 and that axis
    sharded over ``axis`` — the contiguous slot cache
    ``[L, 2, B, Hkv, Smax, Dh]`` or the paged pool
    ``[L, 2, N, Hkv, block, Dh]``.  ``kv_perm [L, Hkv]`` is the GLOBAL
    delta shuffle (new kv slot -> previous kv slot) from
    :meth:`repro.core.planner.PlanDelta.kv_perm_table`; a replan may move
    a kv head BETWEEN shards, so the island all-gathers the kv-head axis
    and each shard takes its new heads (one collective per swap — epoch
    swaps are rare; a production mesh would ppermute only the moved
    heads).  Single-shard callers should use
    ``models.transformer.permute_cache_kv_heads`` directly (no
    collective).
    """
    def repermute(cache, kv_perm, scales=None):
        def island(c_l, perm_l):
            # c_l [L, 2, *, Hkv_loc, *, Dh] (or a scales tensor with kv
            # heads on axis 3); perm_l [L, Hkv] replicated
            full = jax.lax.all_gather(c_l, axis, axis=3, tiled=True)
            d = jax.lax.axis_index(axis)
            hl = c_l.shape[3]
            mine = jax.lax.dynamic_slice_in_dim(
                jnp.asarray(perm_l, jnp.int32), d * hl, hl, axis=1)
            idx = mine.reshape((mine.shape[0], 1, 1, hl)
                               + (1,) * (c_l.ndim - 4))
            return jnp.take_along_axis(full, idx, axis=3)

        def run(x):
            nd = np.asarray(x.ndim)
            spec = P(*((None, None, None, axis) + (None,) * (int(nd) - 4)))
            return shard_map(
                island, mesh=mesh,
                in_specs=(spec, P(None, None)),
                out_specs=spec,
                check_vma=False,
            )(x, jnp.asarray(kv_perm, jnp.int32))

        if scales is None:
            return run(cache)
        # quantized (§2.12): the scales tensor — paged [L, 2, N, Hkv] or
        # contiguous [L, 2, B, Hkv, S/blk], kv heads on axis 3 like the
        # cache — re-permutes through the identical island
        return run(cache), run(scales)

    return repermute


def hplb_swap_gather_kv_blocks(mesh, *, axis="model"):
    """Preemption swap-out island (DESIGN.md §2.10): gather a preempted
    sequence's mapped pool blocks off a HEAD-SHARDED cache, shard-LOCAL.

    ``pool [L, 2, N, Hkv, block, Dh]`` has its kv-head axis sharded over
    ``axis``; ``ids [nblk]`` (pool-global block ids, trash-padded) are
    replicated.  Each shard slices ITS OWN kv-head rows of the selected
    blocks — no collective, unlike the epoch re-permute above — so the
    host copy comes back still laid out in the CURRENT epoch's kv-head
    arrangement.  That is exactly why a plan-epoch re-permute between
    swap-out and swap-in must re-arrange the host copy once at swap-in
    (the engine tracks the cumulative arrangement; the resident cache's
    §2.9 gather never touches host copies).  The pool passes through
    donated/aliased so the jitted caller keeps the buffer chain.
    """
    def gather(pool, ids, scales=None):
        def island(p_l, ids_l):
            # p_l [L, 2, N, Hkv_loc, block, Dh] (or scales [L, 2, N,
            # Hkv_loc]): local take on the block axis, no collective
            return p_l, jnp.take(p_l, ids_l, axis=2)

        def run(x):
            spec = P(*((None, None, None, axis) + (None,) * (x.ndim - 4)))
            return shard_map(
                island, mesh=mesh,
                in_specs=(spec, P(None)),
                out_specs=(spec, spec),
                check_vma=False,
            )(x, jnp.asarray(ids, jnp.int32))

        if scales is None:
            return run(pool)
        # quantized (§2.12): scales [L, 2, N, Hkv] gather through the same
        # ids — the host swap copy is (codes, scales), byte-true
        (pool, blocks), (scales, sc) = run(pool), run(scales)
        return (pool, scales), (blocks, sc)

    return gather


def hplb_swap_scatter_kv_blocks(mesh, *, axis="model"):
    """Preemption swap-in island: scatter a host copy back into freshly
    mapped pool blocks, shard-local (each shard writes its own kv-head
    slice; trash-padded ids absorb the bucket padding).  The host copy
    must already be in the CURRENT epoch's kv-head arrangement — the
    engine re-arranges stale copies host-side before dispatch."""
    def scatter(pool, blocks, ids, scales=None, block_scales=None):
        def island(p_l, b_l, ids_l):
            return p_l.at[:, :, ids_l].set(b_l.astype(p_l.dtype))

        def run(x, b):
            spec = P(*((None, None, None, axis) + (None,) * (x.ndim - 4)))
            return shard_map(
                island, mesh=mesh,
                in_specs=(spec, spec, P(None)),
                out_specs=spec,
                check_vma=False,
            )(x, b, jnp.asarray(ids, jnp.int32))

        if scales is None:
            return run(pool, blocks)
        return run(pool, blocks), run(scales, block_scales)

    return scatter


def flash_decode_attention_paged(mesh, *, block_kv=128, seq_axes=("model",),
                                 batch_axes=None):
    """Paged twin of :func:`flash_decode_attention`: the device cache is a
    block POOL ``[N, Hkv, block, D]`` sharded on its BLOCK axis over
    ``seq_axes`` (each shard owns pool blocks ``[s*N_loc, (s+1)*N_loc)``),
    and selections stay LOGICAL — the per-slot block table ``[B, T]``
    (pool-GLOBAL ids) is remapped shard-local inside the island, entries
    another shard owns becoming -1 (masked).  Because positions derive
    from the logical ids, no position shifting is needed; partials merge
    with the same flash-decoding psum/pmax combine.  S-HPLB balance now
    acts on the one true unit: per-shard POOL BLOCK counts.
    """
    if batch_axes is None:
        batch_axes = tuple(a for a in _batch_axes(mesh)
                           if a not in seq_axes)
    ba = tuple(batch_axes)
    bspec = ba[0] if len(ba) == 1 else (ba if ba else None)
    sspec = seq_axes[0] if len(seq_axes) == 1 else tuple(seq_axes)

    def attend(q, k_pool, v_pool, ids, table, pos, k_scales=None,
               v_scales=None):
        B, H, _, dh = q.shape
        hkv = k_pool.shape[1]
        G = H // hkv
        n_pool = k_pool.shape[0]
        n_shards = int(np.prod([mesh.shape[a] for a in seq_axes]))
        n_loc = n_pool // n_shards
        # per-slot positions shard with the batch like q/ids/table do
        pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        qz = k_scales is not None

        def island(q_l, kp_l, vp_l, ids_l, tbl_l, pos_l, ks_l=None,
                   vs_l=None):
            # q_l [B_l, H, 1, D]; kp_l [N_loc, Hkv, blk, D];
            # ids_l [B_l, Hkv, nb] LOGICAL; tbl_l [B_l, T] GLOBAL pool ids
            if len(seq_axes) == 1:
                sidx = jax.lax.axis_index(seq_axes[0])
            else:
                sidx = jax.lax.axis_index(seq_axes)
            lo = sidx * n_loc
            local = tbl_l - lo
            ok = (tbl_l >= 0) & (local >= 0) & (local < n_loc)
            tbl_local = jnp.where(ok, local, -1)
            Bl = q_l.shape[0]
            out, m, l = ops.flash_decode_paged(
                q_l, kp_l, vp_l, ids_l, tbl_local, pos_l,
                block_kv=block_kv, partials=True,
                k_scales=ks_l, v_scales=vs_l)
            ax = seq_axes if len(seq_axes) > 1 else seq_axes[0]
            gm = jax.lax.pmax(m, ax)                          # [B,hkv,G]
            w = jnp.exp(m - gm) * l
            den = jax.lax.psum(w, ax)
            num = jax.lax.psum(
                out.astype(jnp.float32).reshape(Bl, hkv, G, dh)
                * w[..., None], ax)
            o = num / jnp.maximum(den, 1e-30)[..., None]
            return o.reshape(Bl, H, 1, dh).astype(q_l.dtype)

        in_specs = (P(bspec, None, None, None),
                    P(sspec, None, None, None),
                    P(sspec, None, None, None),
                    P(bspec, None, None),
                    P(bspec, None),
                    P(bspec))
        args = (q, k_pool, v_pool, ids, table, pos_b)
        if qz:
            # quantized (§2.12): scales [N, Hkv] (PHYSICAL ids) shard on
            # the block axis WITH their pool stripe — the translated local
            # table indexes the local scales shard directly
            in_specs += (P(sspec, None), P(sspec, None))
            args += (k_scales, v_scales)
        return shard_map(
            island, mesh=mesh, in_specs=in_specs,
            out_specs=P(bspec, None, None, None),
            check_vma=False,
        )(*args)

    return attend


def flash_decode_attention_2d(mesh, *, block_kv=128, model_axis="model",
                              seq_axis="seq", batch_axes=None):
    """2D head x sequence decode island (DESIGN.md §2.11).

    The paged pool ``[N, Hkv, block, D]`` is sharded BOTH ways: kv heads
    over ``model_axis`` (the HPLB axis) and pool blocks over ``seq_axis``
    (contiguous stripes of ``N_loc = N // n_seq`` ids — exactly the
    stripe-aware allocator's ownership ranges).  Each device ``(d, s)``
    computes flash-decode partials for ITS kv-head shard over ITS stripe's
    blocks: the GLOBAL per-slot table ``[B, T]`` is remapped stripe-local
    inside the island (foreign/unmapped entries become -1, masked), so
    selections stay LOGICAL and shard with their kv heads over
    ``model_axis``.  Partials merge with ONE psum/pmax flash-decoding
    combine along ``seq_axis`` ONLY — heads are disjoint along
    ``model_axis``, so no collective ever crosses it.  A stripe holding
    none of a row's blocks contributes ``l = 0`` weights and drops out of
    the merge exactly (``NEG_INF`` is finite — no 0/0).
    """
    if batch_axes is None:
        batch_axes = tuple(a for a in _batch_axes(mesh)
                           if a not in (model_axis, seq_axis))
    ba = tuple(batch_axes)
    bspec = ba[0] if len(ba) == 1 else (ba if ba else None)

    def attend(q, k_pool, v_pool, ids, table, pos, k_scales=None,
               v_scales=None):
        B, H, _, dh = q.shape
        n_pool = k_pool.shape[0]
        n_seq = mesh.shape[seq_axis]
        n_loc = n_pool // n_seq
        pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
        qz = k_scales is not None

        def island(q_l, kp_l, vp_l, ids_l, tbl_l, pos_l, ks_l=None,
                   vs_l=None):
            # q_l [B_l, H_loc, 1, D]; kp_l [N_loc, Hkv_loc, blk, D];
            # ids_l [B_l, Hkv_loc, nb] LOGICAL; tbl_l [B_l, T] GLOBAL
            sidx = jax.lax.axis_index(seq_axis)
            lo = sidx * n_loc
            local = tbl_l - lo
            ok = (tbl_l >= 0) & (local >= 0) & (local < n_loc)
            tbl_local = jnp.where(ok, local, -1)
            Bl, Hl = q_l.shape[0], q_l.shape[1]
            hkv_l = kp_l.shape[1]
            G = Hl // hkv_l
            out, m, l = ops.flash_decode_paged(
                q_l, kp_l, vp_l, ids_l, tbl_local, pos_l,
                block_kv=block_kv, partials=True,
                k_scales=ks_l, v_scales=vs_l)
            gm = jax.lax.pmax(m, seq_axis)                # [B,hkv_l,G]
            w = jnp.exp(m - gm) * l
            den = jax.lax.psum(w, seq_axis)
            num = jax.lax.psum(
                out.astype(jnp.float32).reshape(Bl, hkv_l, G, dh)
                * w[..., None], seq_axis)
            o = num / jnp.maximum(den, 1e-30)[..., None]
            return o.reshape(Bl, Hl, 1, dh).astype(q_l.dtype)

        in_specs = (P(bspec, model_axis, None, None),
                    P(seq_axis, model_axis, None, None),
                    P(seq_axis, model_axis, None, None),
                    P(bspec, model_axis, None),
                    P(bspec, None),
                    P(bspec))
        args = (q, k_pool, v_pool, ids, table, pos_b)
        if qz:
            # quantized (§2.12): scales [N, Hkv] shard BOTH ways with the
            # pool — blocks over seq, kv heads over model
            in_specs += (P(seq_axis, model_axis), P(seq_axis, model_axis))
            args += (k_scales, v_scales)
        return shard_map(
            island, mesh=mesh, in_specs=in_specs,
            out_specs=P(bspec, model_axis, None, None),
            check_vma=False,
        )(*args)

    return attend


def flash_decode_attention(mesh, *, block_kv=128, seq_axes=("model",),
                           batch_axes=None):
    """Build the shard_map budgeted flash-decode: (q, kc, vc, ids, pos) -> o.

    kc/vc [B, Hkv, S, D] sharded on S over ``seq_axes``; ids
    [n_shards, Hkv, nb_loc] int32 GLOBAL block indices owned by each shard
    (-1 padding), sharded on axis 0.  q [B, H, 1, D] replicated over
    seq_axes.  Partial (acc, m, l) per shard; psum-merge over seq_axes.
    ``batch_axes``: axes sharding the batch dim (default: all of pod/data
    not used for seq; pass () when B is too small to shard — long_500k B=1).
    """
    if batch_axes is None:
        batch_axes = tuple(a for a in _batch_axes(mesh)
                           if a not in seq_axes)
    ba = tuple(batch_axes)
    bspec = ba[0] if len(ba) == 1 else (ba if ba else None)
    sspec = seq_axes[0] if len(seq_axes) == 1 else tuple(seq_axes)

    def attend(q, kc, vc, ids, pos, k_scales=None, v_scales=None):
        B, H, _, dh = q.shape
        hkv = kc.shape[1]
        G = H // hkv
        smax = kc.shape[2]
        n_shards = int(np.prod([mesh.shape[a] for a in seq_axes]))
        s_loc = smax // n_shards
        nblk_loc = s_loc // block_kv
        qz = k_scales is not None

        def island(q_l, kc_l, vc_l, ids_l, ks_l=None, vs_l=None):
            # q_l [B_l, H, 1, D]; kc_l [B_l, Hkv, S_loc, D];
            # ids_l [1, Hkv, nb_loc] (global block ids)
            if len(seq_axes) == 1:
                sidx = jax.lax.axis_index(seq_axes[0])
            else:
                sidx = jax.lax.axis_index(seq_axes)
            ids0 = ids_l[0]                                   # [Hkv, nb_loc]
            local = ids0 - sidx * nblk_loc
            ok = (ids0 >= 0) & (local >= 0) & (local < nblk_loc)
            local_ids = jnp.where(ok, local, -1)
            Bl = kc_l.shape[0]
            # fused budgeted flash-decode against the LOCAL cache shard —
            # streams only this shard's selected blocks, no dense gather.
            # Positions shift by the shard's token offset so the in-kernel
            # `kpos <= pos` mask matches global causality.
            pos_local = (jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (Bl,))
                         - sidx * nblk_loc * block_kv)
            out, m, l = ops.flash_decode(
                q_l, kc_l, vc_l,
                jnp.broadcast_to(local_ids[None],
                                 (Bl, hkv, local_ids.shape[-1])),
                pos_local, block_kv=block_kv, partials=True,
                k_scales=ks_l, v_scales=vs_l)
            # flash-decoding merge across seq shards
            ax = seq_axes if len(seq_axes) > 1 else seq_axes[0]
            gm = jax.lax.pmax(m, ax)                          # [B,hkv,G]
            w = jnp.exp(m - gm) * l
            den = jax.lax.psum(w, ax)
            num = jax.lax.psum(
                out.astype(jnp.float32).reshape(Bl, hkv, G, dh)
                * w[..., None], ax)
            o = num / jnp.maximum(den, 1e-30)[..., None]
            return o.reshape(Bl, H, 1, dh).astype(q_l.dtype)

        in_specs = (P(bspec, None, None, None),
                    P(bspec, None, sspec, None),
                    P(bspec, None, sspec, None),
                    P(sspec, None, None))
        args = (q, kc, vc, ids)
        if qz:
            # quantized (§2.12): scales [B, Hkv, S/blk] shard on the BLOCK
            # axis with their cache rows — the shard-local block ids index
            # the local scales slice directly
            in_specs += (P(bspec, None, sspec), P(bspec, None, sspec))
            args += (k_scales, v_scales)
        return shard_map(
            island, mesh=mesh, in_specs=in_specs,
            out_specs=P(bspec, None, None, None),
            check_vma=False,
        )(*args)

    return attend
