"""Version compatibility shims for the jax sharding APIs this repo uses.

Two call sites moved across jax releases:

- ``shard_map``: new jax exports it at top level (``jax.shard_map``) with a
  ``check_vma`` kwarg; 0.4.x only has ``jax.experimental.shard_map`` whose
  equivalent kwarg is ``check_rep``.
- ``set_mesh``: new jax has ``jax.set_mesh(mesh)`` as a context manager;
  0.4.x uses the ``Mesh`` object itself as the context.

Everything else (``Mesh``, ``PartitionSpec``, ``NamedSharding``,
``jax.make_mesh``) is stable across the supported range.
"""
from __future__ import annotations

import contextlib
import inspect

import jax

try:  # jax >= 0.6 style
    from jax import shard_map as _shard_map
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` kwarg rename
    papered over (the replication check is what both names control)."""
    kw = {"mesh": mesh, "in_specs": in_specs, "out_specs": out_specs}
    if "check_vma" in _SHARD_MAP_PARAMS:
        kw["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kw["check_rep"] = check_vma
    return _shard_map(f, **kw)


def set_mesh(mesh):
    """Context manager activating ``mesh`` for jit/sharding resolution."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    if hasattr(mesh, "__enter__"):  # 0.4.x: Mesh is its own context
        return mesh
    return contextlib.nullcontext(mesh)


def abstract_mesh(axis_sizes, axis_names):
    """``jax.sharding.AbstractMesh`` across the signature change (new:
    ``(sizes, names)``; 0.4.x: a single ``((name, size), ...)`` tuple)."""
    AM = jax.sharding.AbstractMesh
    try:
        return AM(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AM(tuple(zip(axis_names, axis_sizes)))


def get_abstract_mesh():
    """The mesh currently activated via :func:`set_mesh` (or None)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    try:  # 0.4.x: the Mesh context manager sets thread_resources
        from jax._src.mesh import thread_resources
        return thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - very old/new jax
        return None


__all__ = ["shard_map", "set_mesh", "get_abstract_mesh", "abstract_mesh"]
