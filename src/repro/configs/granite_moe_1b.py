"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base].

MoE FFN shards experts over the model axis (EP); attention side takes
S-HPLB budgets normally — the AFD-style composition of the paper."""
from repro.configs.base import ArchSpec
from repro.models.moe import MoEConfig
from repro.models.transformer import TransformerConfig

FULL = TransformerConfig(
    name="granite-moe-1b-a400m",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155, head_dim=64,
    attn_pattern="G", tie_embeddings=True,
    moe=MoEConfig(num_experts=32, experts_per_token=8),
)

SMOKE = TransformerConfig(
    name="granite-moe-smoke",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=512, head_dim=16,
    attn_pattern="G", tie_embeddings=True,
    moe=MoEConfig(num_experts=4, experts_per_token=2),
    layer_loop="unroll",
)

SPEC = ArchSpec(
    arch_id="granite-moe-1b-a400m", family="moe", module="transformer",
    full=FULL, smoke=SMOKE, hplb="full", long_mode="sparse",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
