"""Pallas TPU kernels for the attention hot-spots S-HPLB optimizes.

- ``flash_attn``     : dense flash attention (baseline).
- ``sparse_prefill`` : work-list block-sparse flash (the S-HPLB mechanism).
- ``sparse_decode``  : work-list budgeted decode against a KV cache.

Use via ``repro.kernels.ops``; oracles in ``repro.kernels.ref``.
"""
from repro.kernels import ops, ref
from repro.kernels.ops import flash_attention, sparse_prefill, sparse_decode
