"""Step-function builders for the dry-run, training and serving launchers.

For every (arch x shape) cell this module produces:
    step_fn      — the pure function to jit (train_step / prefill / decode),
    abstract     — the full kwargs tree of ShapeDtypeStructs,
    in_shardings / out_shardings — NamedSharding trees for the mesh.

Serve-shape policy (the paper's system IS the baseline):
- ``prefill_32k``  lowers S-HPLB sparse prefill: shard_map work-list islands
  over the model axis, per-device lists from the HPLB plan (max-min budgets
  + balanced partition).  Work-list shapes are computed host-side from the
  plan (numpy, fast) — they are static per (arch, shape, mesh).
- ``decode_32k`` / ``long_500k`` lower the budgeted flash-decode against a
  sequence-sharded KV cache (shard_map partial-softmax combine), with
  per-shard block-id lists balanced by the same plan.
- non-attention archs (mamba2) and hybrid/enc-dec archs lower their native
  decode paths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec
from repro.configs.inputs import input_specs
from repro.configs.shapes import ShapeSpec
from repro.core import quant
from repro.core.planner import make_plan
from repro.core.sparsity import synthetic_head_curves
from repro.core.worklist import worklist_from_budgets
from repro.attention.policies import policy_by_name
from repro.serving.sharded_attention import (
    flash_decode_attention,
    hplb_prefill_attention,
)
from repro.sharding import specs as sh
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import TrainConfig, make_train_step

# Serving plan defaults (paper setting: k=4096 at 32k+ contexts).
SERVE_BUDGET_PER_HEAD = 4096
BLOCK = 128


@dataclasses.dataclass
class BuiltStep:
    name: str
    fn: Callable
    abstract: dict            # kwargs of ShapeDtypeStruct
    in_shardings: dict
    out_shardings: Any
    meta: dict


def _named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _loss_fn_for(spec: ArchSpec):
    if spec.module == "transformer":
        from repro.models.transformer import loss_fn
        return functools.partial(loss_fn, cfg=spec.full)
    if spec.module == "mamba2":
        from repro.models.mamba2 import loss_fn
        return functools.partial(loss_fn, cfg=spec.full)
    if spec.module == "rglru":
        from repro.models.rglru import loss_fn
        return functools.partial(loss_fn, cfg=spec.full)
    if spec.module == "whisper":
        from repro.models.whisper import loss_fn
        return functools.partial(loss_fn, cfg=spec.full)
    if spec.module == "llava":
        from repro.models.llava import loss_fn
        return functools.partial(loss_fn, cfg=spec.full)
    raise ValueError(spec.module)


def _init_fn_for(spec: ArchSpec):
    mod = spec.module
    if mod == "transformer":
        from repro.models.transformer import init_params
        return functools.partial(init_params, cfg=spec.full)
    if mod == "mamba2":
        from repro.models.mamba2 import init_params
        return functools.partial(init_params, cfg=spec.full)
    if mod == "rglru":
        from repro.models.rglru import init_params
        return functools.partial(init_params, cfg=spec.full)
    if mod == "whisper":
        from repro.models.whisper import init_params
        return functools.partial(init_params, cfg=spec.full)
    if mod == "llava":
        from repro.models.llava import init_params
        return functools.partial(init_params, cfg=spec.full)
    raise ValueError(mod)


def _abstract_params(spec: ArchSpec):
    init = _init_fn_for(spec)
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0)))


def _hp_degree(cfg, model_shards: int) -> int:
    """Head-parallel degree for the plan: the mesh's model size when head
    atoms divide it, else 1 (row-mode partitions (head, q_blk) rows across
    the mesh instead; budgets are device-count-independent)."""
    H, Hkv = cfg.num_heads, cfg.num_kv_heads
    if H % model_shards == 0 and (Hkv % model_shards == 0
                                  or H % model_shards == 0):
        return model_shards
    return 1


def _serve_plan(spec: ArchSpec, seq_len: int, model_shards: int,
                allocator: str = "maxmin", partitioner: str = "best"):
    """HPLB plan for serving cells (synthetic profile: planning is
    profile-shape-agnostic; real deployments feed measured profiles)."""
    cfg = spec.full if spec.module != "llava" else spec.full.backbone
    prof = synthetic_head_curves(cfg.num_layers, cfg.num_heads)
    hp = _hp_degree(cfg, model_shards)
    return make_plan(
        prof, num_devices=hp, num_kv_heads=cfg.num_kv_heads,
        seq_len=seq_len,
        total_budget_per_head=min(SERVE_BUDGET_PER_HEAD, seq_len),
        block=BLOCK, allocator=allocator, partitioner=partitioner,
    ), cfg


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def build_train_step(spec: ArchSpec, shape: ShapeSpec, mesh,
                     *, remat: str = "full",
                     microbatches: int = 1,
                     compress_grads: bool = False,
                     moe_cf: float | None = None,
                     moe_int8_dispatch: bool = False) -> BuiltStep:
    if (moe_cf is not None or moe_int8_dispatch) \
            and getattr(spec.full, "moe", None) is not None:
        new_moe = dataclasses.replace(
            spec.full.moe,
            capacity_factor=moe_cf or spec.full.moe.capacity_factor,
            quantize_dispatch=moe_int8_dispatch)
        spec = dataclasses.replace(
            spec, full=dataclasses.replace(spec.full, moe=new_moe))
    loss_fn = _loss_fn_for(spec)
    tcfg = TrainConfig(optimizer=AdamWConfig(), remat=remat,
                       microbatches=microbatches,
                       compress_grads=compress_grads)
    step = make_train_step(loss_fn, tcfg)

    from repro.training.optimizer import init_opt_state
    params_a = _abstract_params(spec)
    opt_a = jax.eval_shape(init_opt_state, params_a)
    state_a = {"params": params_a, "opt": opt_a}
    if compress_grads:
        state_a["err"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_a)
    batch_a = input_specs(spec, shape)

    pspec = sh.param_specs(params_a, mesh)
    ospec = sh.opt_specs(opt_a, pspec)
    bspec = sh.batch_specs(batch_a, mesh)

    state_spec = {"params": pspec, "opt": ospec}
    if compress_grads:
        state_spec["err"] = pspec
    in_sh = {"state": _named(mesh, state_spec),
             "batch": _named(mesh, bspec)}
    out_sh = (in_sh["state"],
              jax.tree.map(lambda _: NamedSharding(mesh, P()),
                           {"loss": 0, "grad_norm": 0, "lr": 0}))
    return BuiltStep(
        name=f"{spec.arch_id}:{shape.name}:train",
        fn=step,
        abstract={"state": state_a, "batch": batch_a},
        in_shardings=in_sh,
        out_shardings=out_sh,
        meta={"kind": "train"},
    )


# ---------------------------------------------------------------------------
# Prefill (S-HPLB sparse for attention archs; native otherwise)
# ---------------------------------------------------------------------------

def build_prefill_step(spec: ArchSpec, shape: ShapeSpec, mesh,
                       *, sparse: bool = True,
                       allocator: str = "maxmin",
                       partitioner: str = "best",
                       force_rows: bool = False) -> BuiltStep:
    B, S = shape.global_batch, shape.seq_len
    params_a = _abstract_params(spec)
    pspec = sh.param_specs(params_a, mesh)
    batch_a = input_specs(spec, shape)
    bspec = sh.batch_specs(batch_a, mesh)
    model_shards = mesh.shape.get("model", 1)

    if spec.module in ("transformer", "llava") and sparse \
            and spec.hplb != "none":
        from repro.models import transformer as tfm
        from repro.core.worklist import blocks_for_budget, build_row_worklist
        from repro.serving.sharded_attention import (
            hplb_prefill_attention_rows)
        plan, cfg = _serve_plan(spec, S, model_shards,
                                allocator=allocator, partitioner=partitioner)
        pol = policy_by_name("strided")
        row_mode = force_rows or plan.num_devices != model_shards
        kv_sharded = (not row_mode) and plan.mode == "kv_group"
        # per-layer per-device work-lists, stacked [n_model, L, Lpad, 7]
        wls = []
        nq = -(-S // BLOCK)
        for l in range(cfg.num_layers):
            lp = plan.layers[l]
            if row_mode:
                # (head, q_blk) row partition — head count doesn't divide
                # the mesh (gemma3: 4 heads / 16 shards; llama4: 40 / 16).
                # Weights stay UNPERMUTED (q/k/v replicated in the island),
                # so budgets/ids are in ORIGINAL head order.
                budgets_orig = plan.budgets_by_original_head(l)
                nb = blocks_for_budget(budgets_orig, BLOCK)
                sels = [pol(h, int(nb[h]), nq, nq)
                        for h in range(cfg.num_heads)]
                wls.append(build_row_worklist(
                    sels, num_devices=model_shards, num_q_blocks=nq,
                    num_kv_blocks=nq, block=BLOCK,
                    kv_head_of_head=np.arange(cfg.num_heads)
                    // cfg.group_size))
            elif kv_sharded:
                wls.append(worklist_from_budgets(
                    lp.budgets, num_devices=model_shards,
                    seq_len=S, block=BLOCK, policy_fn=pol,
                    group_size=cfg.group_size))
            else:
                # kv_replication: kv index = ORIGINAL kv head (global,
                # replicated on every shard)
                wls.append(worklist_from_budgets(
                    lp.budgets, num_devices=model_shards,
                    seq_len=S, block=BLOCK, policy_fn=pol,
                    group_size=cfg.group_size,
                    kv_head_of_head=lp.perm // cfg.group_size,
                    kv_local=False))
        lpad = max(w.padded_length for w in wls)
        items = np.zeros((model_shards, cfg.num_layers, lpad, 7), np.int32)
        for l, w in enumerate(wls):
            items[:, l, :w.padded_length] = w.items
            # pad rows replicate each device's last row (valid=0)
            for d in range(model_shards):
                items[d, l, w.padded_length:] = items[d, l,
                                                      w.padded_length - 1]
                items[d, l, w.padded_length:, 3:6] = 0
        if row_mode:
            attend = hplb_prefill_attention_rows(
                mesh, block_q=BLOCK, block_kv=BLOCK)
        else:
            attend = hplb_prefill_attention(
                mesh, block_q=BLOCK, block_kv=BLOCK, kv_sharded=kv_sharded)

        if spec.module == "llava":
            bb = spec.full.backbone
            def fn(params, tokens, items, patches):
                return tfm.prefill(
                    params, tokens, bb, cache_len=None,
                    attn_override=lambda l, q, k, v: attend(
                        l, q, k, v, items),
                    extra_embeddings=patches)
            abstract = {
                "tokens": batch_a["tokens"],
                "items": jax.ShapeDtypeStruct(items.shape, jnp.int32),
                "patches": batch_a["patches"],
            }
            in_sh = {
                "tokens": NamedSharding(mesh, sh.batch_specs(
                    batch_a, mesh)["tokens"]),
                "items": NamedSharding(mesh, P("model")),
                "patches": NamedSharding(mesh, sh.batch_specs(
                    batch_a, mesh)["patches"]),
            }
        else:
            def fn(params, tokens, items):
                return tfm.prefill(
                    params, tokens, spec.full, cache_len=None,
                    attn_override=lambda l, q, k, v: attend(
                        l, q, k, v, items))
            abstract = {
                "tokens": batch_a["tokens"],
                "items": jax.ShapeDtypeStruct(items.shape, jnp.int32),
            }
            in_sh = {
                "tokens": NamedSharding(mesh, bspec["tokens"]),
                "items": NamedSharding(mesh, P("model")),
            }
        in_sh = {"params": _named(mesh, pspec), **in_sh}
        abstract = {"params": params_a, **abstract}
        meta = {"kind": "prefill", "sparse": True,
                "plan_imbalance": plan.mean_imbalance,
                "worklist_lpad": int(lpad)}
    else:
        # native prefill / forward paths
        if spec.module == "mamba2":
            from repro.models.mamba2 import forward
            fn = lambda params, tokens: forward(params, tokens, spec.full)
        elif spec.module == "rglru":
            from repro.models.rglru import forward
            fn = lambda params, tokens: forward(params, tokens, spec.full)
        elif spec.module == "whisper":
            from repro.models.whisper import forward as wfwd
            fn = lambda params, tokens, frames: wfwd(
                params, {"tokens": tokens, "frames": frames}, spec.full)
        elif spec.module in ("transformer", "llava"):
            from repro.models import transformer as tfm
            cfg = spec.full if spec.module == "transformer" \
                else spec.full.backbone
            if spec.module == "llava":
                def fn(params, tokens, patches):
                    return tfm.prefill(params, tokens, cfg,
                                       extra_embeddings=patches)
            else:
                def fn(params, tokens):
                    return tfm.prefill(params, tokens, cfg)
        else:
            raise ValueError(spec.module)
        abstract = {"params": params_a, **batch_a}
        in_sh = {"params": _named(mesh, pspec),
                 **{k: NamedSharding(mesh, v) for k, v in bspec.items()}}
        meta = {"kind": "prefill", "sparse": False}

    return BuiltStep(
        name=f"{spec.arch_id}:{shape.name}:prefill",
        fn=fn, abstract=abstract, in_shardings=in_sh,
        out_shardings=None, meta=meta)


# ---------------------------------------------------------------------------
# Decode (budgeted flash-decode for attention archs; native otherwise)
# ---------------------------------------------------------------------------

def _decode_block_ids_sharded(plan, cfg, cache_len: int, n_shards: int):
    """Per-shard decode block lists [n_shards, Hkv, nb_loc], -1 padded.

    Budget per kv head = max over its q heads; blocks = sink + recent.
    Blocks are assigned to the seq-shard that OWNS them (global block id //
    blocks_per_shard) — the HPLB-balanced analogue for sequence sharding.
    """
    gsz = cfg.group_size
    nkv_blocks = cache_len // BLOCK
    blocks_per_shard = nkv_blocks // n_shards
    hkv = cfg.num_kv_heads
    budgets = np.stack([
        lp.budgets.reshape(hkv, gsz).max(axis=1) for lp in plan.layers
    ])  # [L, Hkv]
    nb = np.minimum(-(-budgets // BLOCK), nkv_blocks)
    nb_loc = 1
    L = nb.shape[0]
    # use layer-0 budgets for the shared input shape; per-layer lists are
    # stacked on a leading L dim
    ids_layers = []
    for l in range(L):
        shard_lists = [[[] for _ in range(hkv)] for _ in range(n_shards)]
        for h in range(hkv):
            n = int(nb[l, h])
            sel = [0] + list(range(nkv_blocks - (n - 1), nkv_blocks))
            sel = sorted(set(b for b in sel if 0 <= b < nkv_blocks))[:n]
            for b in sel:
                s = min(b // max(blocks_per_shard, 1), n_shards - 1)
                shard_lists[s][h].append(b)
        nb_loc = max(nb_loc, max(len(shard_lists[s][h])
                                 for s in range(n_shards)
                                 for h in range(hkv)))
        ids_layers.append(shard_lists)
    ids = np.full((L, n_shards, hkv, nb_loc), -1, np.int32)
    for l, shard_lists in enumerate(ids_layers):
        for s in range(n_shards):
            for h in range(hkv):
                v = shard_lists[s][h]
                ids[l, s, h, :len(v)] = v
    return ids


def build_decode_step(spec: ArchSpec, shape: ShapeSpec, mesh,
                      *, sparse: bool = True,
                      cache_dtype=None,
                      kv_dtype: str | None = None) -> BuiltStep:
    B, S = shape.global_batch, shape.seq_len
    params_a = _abstract_params(spec)
    pspec = sh.param_specs(params_a, mesh)
    data_a = input_specs(spec, shape)
    model_shards = mesh.shape.get("model", 1)

    if spec.module == "mamba2":
        from repro.models import mamba2 as m2
        cfg = spec.full
        state_a = jax.eval_shape(lambda: m2.init_state(cfg, B))
        fn = lambda params, state, token: m2.decode_step(
            params, state, token, cfg)
        abstract = {"params": params_a, "state": state_a,
                    "token": data_a["token"]}
        in_sh = {"params": _named(mesh, pspec),
                 "state": _named(mesh, sh.cache_specs(state_a, mesh)),
                 "token": NamedSharding(mesh, sh.batch_specs(
                     data_a, mesh)["token"])}
        meta = {"kind": "decode", "native": "ssm"}
    elif spec.module == "rglru":
        from repro.models import rglru as rg
        cfg = spec.full
        state_a = jax.eval_shape(lambda: rg.init_state(cfg, B))
        fn = lambda params, state, token: rg.decode_step(
            params, state, token, S - 1, cfg)
        abstract = {"params": params_a, "state": state_a,
                    "token": data_a["token"]}
        in_sh = {"params": _named(mesh, pspec),
                 "state": _named(mesh, sh.cache_specs(state_a, mesh)),
                 "token": NamedSharding(mesh, sh.batch_specs(
                     data_a, mesh)["token"])}
        meta = {"kind": "decode", "native": "hybrid"}
    elif spec.module == "whisper":
        from repro.models import whisper as wh
        cfg = spec.full
        cache_a = jax.eval_shape(lambda: wh.init_cache(cfg, B, S))
        fn = lambda params, cache, memory, token: wh.decode_step(
            params, cache, memory, token, S - 1, cfg)
        abstract = {"params": params_a, "cache": cache_a,
                    "memory": data_a["memory"], "token": data_a["token"]}
        in_sh = {"params": _named(mesh, pspec),
                 "cache": NamedSharding(mesh, sh.cache_specs(cache_a, mesh)),
                 "memory": NamedSharding(mesh, sh.batch_specs(
                     data_a, mesh)["memory"]),
                 "token": NamedSharding(mesh, sh.batch_specs(
                     data_a, mesh)["token"])}
        meta = {"kind": "decode", "native": "encdec"}
    else:  # transformer / llava: budgeted flash-decode, seq-sharded cache
        from repro.models import transformer as tfm
        cfg = spec.full if spec.module == "transformer" \
            else spec.full.backbone
        qz = kv_dtype is not None and quant.is_quantized(kv_dtype)
        if kv_dtype is not None:
            # real engine-path dtype selection (§2.12): the pool stores
            # int8/fp8 codes; the legacy raw ``cache_dtype`` kwarg is
            # retained for bf16-family experiments only
            cache_dtype = quant.kv_cache_dtype(kv_dtype,
                                               default=cache_dtype)
        pool_a = jax.eval_shape(
            lambda: tfm.init_cache(cfg, B, S, dtype=cache_dtype))
        cache_spec = sh.cache_specs(pool_a, mesh)
        if qz:
            assert cfg.block_kv == BLOCK, \
                "quantized decode step needs cfg.block_kv == plan BLOCK " \
                "(one scale tile per plan block)"
            assert S % BLOCK == 0, "quantized cache needs S % block == 0"
            scales_a = jax.eval_shape(
                lambda: tfm.init_cache_scales(cfg, B, S, BLOCK))
            # scales [L, 2, B, Hkv, S/blk] travel with the cache: same
            # batch / kv-head / seq-block sharding, no head-dim entry
            scales_spec = P(*(tuple(cache_spec)[:5]))
            cache_a = (pool_a, scales_a)
        else:
            cache_a = pool_a
        # seq-shard axes: whatever cache_specs put on the seq dim
        seq_entry = cache_spec[4]
        if seq_entry is None:
            seq_axes = ()
        elif isinstance(seq_entry, tuple):
            seq_axes = seq_entry
        else:
            seq_axes = (seq_entry,)
        if sparse and spec.hplb != "none" and seq_axes:
            plan, _ = _serve_plan(spec, S, model_shards)
            n_sh = int(np.prod([mesh.shape[a] for a in seq_axes]))
            ids = _decode_block_ids_sharded(plan, cfg, S, n_sh)
            batch_axes = tuple(
                a for a in ("pod", "data")
                if a in mesh.axis_names and a not in seq_axes)
            if batch_axes and B % int(np.prod(
                    [mesh.shape[a] for a in batch_axes])) != 0:
                batch_axes = ()
            attend_by_layer = flash_decode_attention(
                mesh, block_kv=BLOCK, seq_axes=seq_axes,
                batch_axes=batch_axes)

            if qz:
                def fn(params, cache, token, ids):
                    pos = S - 1
                    pool, scales = cache
                    logits, pool, scales = tfm.decode_step(
                        params, pool, token, pos, cfg,
                        scales=scales, kv_dtype=kv_dtype,
                        attn_override=lambda l, q, kc, vc, ks, vs:
                            attend_by_layer(q, kc, vc, ids[l], pos,
                                            ks, vs))
                    return logits, (pool, scales)
            else:
                def fn(params, cache, token, ids):
                    pos = S - 1
                    return tfm.decode_step(
                        params, cache, token, pos, cfg,
                        attn_override=lambda l, q, kc, vc: attend_by_layer(
                            q, kc, vc, ids[l], pos))
            abstract = {"params": params_a, "cache": cache_a,
                        "token": data_a["token"],
                        "ids": jax.ShapeDtypeStruct(ids.shape, jnp.int32)}
            sspec = seq_axes[0] if len(seq_axes) == 1 else seq_axes
            cache_sh = (NamedSharding(mesh, cache_spec) if not qz else
                        (NamedSharding(mesh, cache_spec),
                         NamedSharding(mesh, scales_spec)))
            in_sh = {"params": _named(mesh, pspec),
                     "cache": cache_sh,
                     "token": NamedSharding(mesh, sh.batch_specs(
                         data_a, mesh)["token"]),
                     "ids": NamedSharding(mesh, P(None, sspec))}
            meta = {"kind": "decode", "sparse": True,
                    "seq_axes": list(seq_axes),
                    "nb_loc": int(ids.shape[-1]),
                    "kv_dtype": kv_dtype or "bf16"}
        else:
            if qz:
                def fn(params, cache, token):
                    pool, scales = cache
                    logits, pool, scales = tfm.decode_step(
                        params, pool, token, S - 1, cfg,
                        scales=scales, kv_dtype=kv_dtype)
                    return logits, (pool, scales)
            else:
                def fn(params, cache, token):
                    return tfm.decode_step(params, cache, token, S - 1, cfg)
            abstract = {"params": params_a, "cache": cache_a,
                        "token": data_a["token"]}
            cache_sh = (NamedSharding(mesh, cache_spec) if not qz else
                        (NamedSharding(mesh, cache_spec),
                         NamedSharding(mesh, scales_spec)))
            in_sh = {"params": _named(mesh, pspec),
                     "cache": cache_sh,
                     "token": NamedSharding(mesh, sh.batch_specs(
                         data_a, mesh)["token"])}
            meta = {"kind": "decode", "sparse": False,
                    "kv_dtype": kv_dtype or "bf16"}

    return BuiltStep(
        name=f"{spec.arch_id}:{shape.name}:decode",
        fn=fn, abstract=abstract, in_shardings=in_sh,
        out_shardings=None, meta=meta)


def build_step(spec: ArchSpec, shape: ShapeSpec, mesh, **kw) -> BuiltStep:
    if shape.kind == "train":
        return build_train_step(spec, shape, mesh, **kw)
    if shape.kind == "prefill":
        return build_prefill_step(spec, shape, mesh, **kw)
    return build_decode_step(spec, shape, mesh, **kw)
