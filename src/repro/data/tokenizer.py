"""Byte-level tokenizer with a small reserved-special-token region.

Vocabulary: 256 byte values + specials.  Deterministic, dependency-free —
sufficient for the synthetic corpora and the RULER-like task suite (which
are generated directly in token space or from ASCII text).
"""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS, SEP = 256, 257, 258, 259
NUM_SPECIALS = 8
VOCAB_SIZE = 256 + NUM_SPECIALS


def encode(text: str | bytes, add_bos: bool = False,
           add_eos: bool = False) -> np.ndarray:
    b = text.encode("utf-8") if isinstance(text, str) else text
    toks = list(b)
    if add_bos:
        toks = [BOS] + toks
    if add_eos:
        toks = toks + [EOS]
    return np.asarray(toks, dtype=np.int32)


def decode(tokens) -> str:
    bs = bytes(int(t) for t in tokens if 0 <= int(t) < 256)
    return bs.decode("utf-8", errors="replace")


def pad_to(tokens: np.ndarray, length: int) -> np.ndarray:
    out = np.full((length,), PAD, dtype=np.int32)
    n = min(len(tokens), length)
    out[:n] = tokens[:n]
    return out
