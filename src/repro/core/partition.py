"""Head -> device assignment: multiway number partitioning (paper §3.3).

The paper formulates head placement as minimizing the load-imbalance ratio

    I = max_d L_d / mean_d L_d,      L_d = sum_{h in H_d} b_h

over all partitions of the head set into |D| disjoint device groups — an
NP-hard multiway partitioning problem — and solves it with greedy LPT
(Longest Processing Time): sort heads by budget descending, place each on the
currently least-loaded device.  ``O(N log N + N log D)``.

This module provides:

- :func:`naive_partition`    — the pre-paper baseline: heads assigned
                               round-robin / contiguously (what vanilla HP
                               does; paper Fig. 8 imbalance source).
- :func:`lpt_partition`      — the paper's greedy heuristic.
- :func:`kk_partition`       — beyond-paper: Karmarkar–Karp largest
                               differencing method, usually strictly better
                               than LPT for adversarial weights.
- :func:`refine_partition`   — beyond-paper: pairwise move/swap local search
                               (Cong & Lim-style refinement) applied on top
                               of any initial assignment.
- :func:`dp_partition`       — exact DP for small instances (test oracle):
                               O(N * (L+1)^{|D|-1}) as quoted in the paper.
- :func:`best_partition`     — production entry point: LPT and KK both, then
                               refinement, keep the best.

All functions return an :class:`Assignment`; heads may carry an optional
``atoms`` grouping (GQA: query heads must stay with their KV group — see
planner.py) in which case the *items* being partitioned are atoms and the
expansion back to heads happens in the planner.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class Assignment:
    """Result of a head->device partitioning.

    device_of:  ``[N]`` int device index per item.
    loads:      ``[D]`` total budget per device.
    method:     provenance string.
    """

    device_of: np.ndarray
    loads: np.ndarray
    method: str = ""

    @property
    def num_devices(self) -> int:
        return len(self.loads)

    @property
    def imbalance(self) -> float:
        """Paper's objective: I = max_d L_d / mean_d L_d (>= 1)."""
        mean = float(self.loads.mean())
        if mean <= 0:
            return 1.0
        return float(self.loads.max() / mean)

    @property
    def makespan(self) -> int:
        """max_d L_d — under SPMD this is the padded grid length every
        device executes (DESIGN.md §2.1), the true cost on TPU."""
        return int(self.loads.max())

    def groups(self) -> list[list[int]]:
        """Items per device."""
        out: list[list[int]] = [[] for _ in range(self.num_devices)]
        for i, d in enumerate(self.device_of):
            out[int(d)].append(i)
        return out


def _loads_of(weights: np.ndarray, device_of: np.ndarray, D: int) -> np.ndarray:
    loads = np.zeros(D, dtype=np.int64)
    np.add.at(loads, device_of, weights)
    return loads


# ---------------------------------------------------------------------------
# Baseline: what naive head-parallelism does (paper Fig. 8)
# ---------------------------------------------------------------------------

def naive_partition(weights: Sequence[int], num_devices: int,
                    mode: str = "contiguous") -> Assignment:
    """Sequential assignment ignoring weights — the vanilla HP layout.

    ``contiguous``: heads [0..N/D) on device 0, etc. (vLLM/SGLang TP layout).
    ``round_robin``: head i -> device i % D.
    """
    w = np.asarray(weights, dtype=np.int64)
    N, D = len(w), num_devices
    if mode == "contiguous":
        per = -(-N // D)  # ceil
        device_of = np.minimum(np.arange(N) // per, D - 1)
    elif mode == "round_robin":
        device_of = np.arange(N) % D
    else:
        raise ValueError(f"unknown naive mode {mode!r}")
    device_of = device_of.astype(np.int64)
    return Assignment(device_of, _loads_of(w, device_of, D), f"naive-{mode}")


# ---------------------------------------------------------------------------
# Paper: LPT greedy
# ---------------------------------------------------------------------------

def lpt_partition(weights: Sequence[int], num_devices: int) -> Assignment:
    """Greedy LPT (paper §3.3): descending weights onto least-loaded device.

    Heap-based: O(N log N) sort + O(N log D) placement, exactly the
    complexity the paper quotes.
    """
    w = np.asarray(weights, dtype=np.int64)
    N, D = len(w), num_devices
    order = np.argsort(-w, kind="stable")
    device_of = np.zeros(N, dtype=np.int64)
    # heap of (load, device); ties broken by device id for determinism
    heap: list[tuple[int, int]] = [(0, d) for d in range(D)]
    heapq.heapify(heap)
    for i in order:
        load, d = heapq.heappop(heap)
        device_of[i] = d
        heapq.heappush(heap, (load + int(w[i]), d))
    return Assignment(device_of, _loads_of(w, device_of, D), "lpt")


# ---------------------------------------------------------------------------
# Beyond paper: Karmarkar–Karp largest differencing (multiway)
# ---------------------------------------------------------------------------

def kk_partition(weights: Sequence[int], num_devices: int) -> Assignment:
    """Karmarkar–Karp largest differencing method, generalized to D-way.

    Maintain a max-heap of partial solutions, each a D-tuple of (load, items)
    sorted descending; repeatedly merge the two with the largest spread by
    combining largest-with-smallest.  Strictly better than LPT on adversarial
    inputs; same asymptotic cost here (N heads is small).
    """
    w = np.asarray(weights, dtype=np.int64)
    N, D = len(w), num_devices
    if N == 0:
        return Assignment(np.zeros(0, np.int64), np.zeros(D, np.int64), "kk")
    # Each entry: (-spread, tiebreak, loads_desc tuple, groups list aligned to loads)
    counter = itertools.count()
    heap = []
    for i in range(N):
        loads = [int(w[i])] + [0] * (D - 1)
        groups: list[list[int]] = [[i]] + [[] for _ in range(D - 1)]
        heapq.heappush(heap, (-(loads[0] - loads[-1]), next(counter), loads, groups))
    while len(heap) > 1:
        _, _, la, ga = heapq.heappop(heap)
        _, _, lb, gb = heapq.heappop(heap)
        # combine: largest of a with smallest of b, etc. (anti-aligned merge)
        loads = [la[j] + lb[D - 1 - j] for j in range(D)]
        groups = [ga[j] + gb[D - 1 - j] for j in range(D)]
        # re-sort descending by load
        order = sorted(range(D), key=lambda j: -loads[j])
        loads = [loads[j] for j in order]
        groups = [groups[j] for j in order]
        heapq.heappush(heap, (-(loads[0] - loads[-1]), next(counter), loads, groups))
    _, _, loads, groups = heap[0]
    device_of = np.zeros(N, dtype=np.int64)
    for d, g in enumerate(groups):
        for i in g:
            device_of[i] = d
    return Assignment(device_of, _loads_of(w, device_of, D), "kk")


# ---------------------------------------------------------------------------
# Beyond paper: pairwise move/swap refinement (local search)
# ---------------------------------------------------------------------------

def refine_partition(weights: Sequence[int], assignment: Assignment,
                     max_rounds: int = 50) -> Assignment:
    """Improve an assignment with single-item moves and pairwise swaps.

    Classic multiway-partition local search (cf. paper ref [5], Cong & Lim):
    repeatedly try (a) moving one item from the max-loaded device to the
    min-loaded one, (b) swapping an item between max and any other device,
    accepting any change that reduces the makespan.  Converges quickly — each
    accepted step strictly reduces ``max_d L_d``.
    """
    w = np.asarray(weights, dtype=np.int64)
    device_of = assignment.device_of.copy()
    D = assignment.num_devices
    loads = _loads_of(w, device_of, D)
    groups = [list(np.where(device_of == d)[0]) for d in range(D)]

    for _ in range(max_rounds):
        improved = False
        dmax = int(np.argmax(loads))
        # (a) single moves off the busiest device
        for i in sorted(groups[dmax], key=lambda i: -w[i]):
            dmin = int(np.argmin(loads))
            if dmax == dmin:
                break
            new_max_side = loads[dmax] - w[i]
            new_min_side = loads[dmin] + w[i]
            if max(new_max_side, new_min_side) < loads[dmax]:
                groups[dmax].remove(i)
                groups[dmin].append(i)
                device_of[i] = dmin
                loads[dmax] = new_max_side
                loads[dmin] = new_min_side
                improved = True
                dmax = int(np.argmax(loads))
        # (b) pairwise swaps busiest <-> every other
        dmax = int(np.argmax(loads))
        for d in range(D):
            if d == dmax:
                continue
            best = None  # (new_makespan_pair, i, j)
            for i in groups[dmax]:
                for j in groups[d]:
                    delta = int(w[i] - w[j])
                    if delta <= 0:
                        continue
                    na, nb = loads[dmax] - delta, loads[d] + delta
                    if max(na, nb) < loads[dmax]:
                        cand = (max(na, nb), i, j)
                        if best is None or cand < best:
                            best = cand
            if best is not None:
                _, i, j = best
                groups[dmax].remove(i)
                groups[d].remove(j)
                groups[dmax].append(j)
                groups[d].append(i)
                device_of[i], device_of[j] = d, dmax
                delta = int(w[i] - w[j])
                loads[dmax] -= delta
                loads[d] += delta
                improved = True
                dmax = int(np.argmax(loads))
        if not improved:
            break
    return Assignment(device_of, loads, assignment.method + "+refine")


# ---------------------------------------------------------------------------
# Exact DP oracle (small instances only)
# ---------------------------------------------------------------------------

def dp_partition(weights: Sequence[int], num_devices: int,
                 max_states: int = 2_000_000) -> Assignment:
    """Exact multiway partition via DP over load vectors (test oracle).

    State: sorted tuple of device loads after placing a prefix of items
    (items sorted descending for pruning).  Complexity O(N * L^{D-1}) as in
    the paper's discussion — only feasible for small N, D, L.  Raises if the
    state space exceeds ``max_states``.
    """
    w = np.asarray(weights, dtype=np.int64)
    N, D = len(w), num_devices
    order = np.argsort(-w, kind="stable")
    # states keyed by SORTED load tuple (dedup/symmetry); value carries the
    # UNSORTED load vector + assignment with consistent device labels.
    states: dict[tuple, tuple[list[int], np.ndarray]] = {
        tuple([0] * D): ([0] * D, np.full(N, -1, np.int64))
    }
    for i in order:
        nxt: dict[tuple, tuple[list[int], np.ndarray]] = {}
        for _, (loads, assign) in states.items():
            seen_loads = set()
            for d in range(D):
                if loads[d] in seen_loads:  # symmetry pruning
                    continue
                seen_loads.add(loads[d])
                nl = list(loads)
                nl[d] += int(w[i])
                key = tuple(sorted(nl))
                if key not in nxt:  # same load vector => equivalent state
                    na = assign.copy()
                    na[i] = d
                    nxt[key] = (nl, na)
        if len(nxt) > max_states:
            raise ValueError(
                f"dp_partition state space {len(nxt)} exceeds {max_states}")
        states = nxt
    best_key = min(states, key=lambda k: (max(k), k))
    _, best_assign = states[best_key]
    loads = _loads_of(w, best_assign, D)
    return Assignment(best_assign, loads, "dp-exact")


def lpt_bound(weights: Sequence[int], num_devices: int) -> float:
    """Upper bound on greedy list-scheduling makespan (Graham):

        max_d L_d  <=  sum(w) / D  +  (1 - 1/D) * max(w)

    Every partitioner in this module (LPT, KK, refinement, best) satisfies
    it, so property tests use it as the contract the cost-packed decode
    worklists must honor: no shard's grid exceeds its fair share by more
    than one maximal run.
    """
    w = np.asarray(weights, dtype=np.int64)
    if len(w) == 0:
        return 0.0
    D = num_devices
    return float(w.sum()) / D + (1.0 - 1.0 / D) * float(w.max())


# ---------------------------------------------------------------------------
# 2D head x sequence packing (DESIGN.md §2.11)
# ---------------------------------------------------------------------------
#
# Sequence-parallel long context adds a second mesh axis: each item (a
# (slot, kv_head) decode run) carries a WEIGHT VECTOR over the `seq`
# stripes — W[i, s] = how many of item i's selected kv blocks live on
# stripe s.  The stripe coordinate of the work is FIXED by data placement
# (a block is computed where it resides); the packer only chooses the
# item's model shard.  The objective generalizes to the max CELL load
#
#     min max_{(d, s)} L_{d,s},   L_{d,s} = sum_{i: dev(i)=d} W[i, s]
#
# because under SPMD every (model, seq) device executes its cell's padded
# grid — the 2D makespan is the grid length everyone pays.


@dataclasses.dataclass
class Assignment2D:
    """Result of a 2D (model x seq) partitioning.

    device_of: ``[N]`` model-shard index per item (the free axis).
    loads:     ``[Dm, Ds]`` per-cell load (stripe axis fixed by the data).
    method:    provenance string.
    """

    device_of: np.ndarray
    loads: np.ndarray
    method: str = ""

    @property
    def num_devices(self) -> int:
        return self.loads.shape[0]

    @property
    def num_stripes(self) -> int:
        return self.loads.shape[1]

    @property
    def makespan(self) -> int:
        """max cell load — the padded 2D grid length under SPMD."""
        return int(self.loads.max())

    @property
    def imbalance(self) -> float:
        """max cell / mean cell (>= 1) — the 2D analogue of the paper's I."""
        mean = float(self.loads.mean())
        return float(self.loads.max() / mean) if mean > 0 else 1.0

    @property
    def model_loads(self) -> np.ndarray:
        """``[Dm]`` per-model-shard totals (summed over stripes)."""
        return self.loads.sum(axis=1)

    @property
    def stripe_loads(self) -> np.ndarray:
        """``[Ds]`` per-stripe totals (summed over model shards)."""
        return self.loads.sum(axis=0)

    @property
    def model_imbalance(self) -> float:
        m = self.model_loads.astype(np.float64)
        mean = float(m.mean())
        return float(m.max() / mean) if mean > 0 else 1.0

    @property
    def stripe_imbalance(self) -> float:
        s = self.stripe_loads.astype(np.float64)
        mean = float(s.mean())
        return float(s.max() / mean) if mean > 0 else 1.0


def _loads_2d(W: np.ndarray, device_of: np.ndarray, Dm: int) -> np.ndarray:
    loads = np.zeros((Dm, W.shape[1]), dtype=np.int64)
    np.add.at(loads, device_of, W)
    return loads


def lpt_bound_2d(weights_2d: np.ndarray, num_devices: int) -> float:
    """2D packer contract: ``max cell load <= lpt_bound(row totals, Dm)``.

    Any cell's load is bounded by its model shard's TOTAL (the sum of the
    shard's cells), and placing items by their row totals with LPT keeps
    every shard total within Graham's bound — so seeding from LPT-on-totals
    and only accepting refinement steps that strictly reduce the max cell
    preserves the 1D contract verbatim on the harder 2D objective.  The
    property tests (tests/test_core_partition.py) hold every 2D packer
    output to this bound.
    """
    W = np.asarray(weights_2d, dtype=np.int64)
    if W.size == 0:
        return 0.0
    return lpt_bound(W.sum(axis=1), num_devices)


def refine_partition_2d(weights_2d: np.ndarray, assignment: Assignment2D,
                        max_rounds: int = 50) -> Assignment2D:
    """Local search on the 2D objective: move single items off the model
    shard holding the max cell, accepting only strict max-cell reductions
    (so :func:`lpt_bound_2d` is preserved by construction)."""
    W = np.asarray(weights_2d, dtype=np.int64)
    device_of = assignment.device_of.copy()
    Dm = assignment.num_devices
    loads = _loads_2d(W, device_of, Dm)

    for _ in range(max_rounds):
        cur = int(loads.max())
        row_max = loads.max(axis=1)
        dmax = int(np.argmax(row_max))
        moved = False
        # one accepted move per round: the max cell may migrate to another
        # shard, so the candidate item set must be re-derived from scratch
        for i in sorted(np.where(device_of == dmax)[0],
                        key=lambda i: -int(W[i].sum())):
            best = None  # (new_global_max, target shard)
            for d in range(Dm):
                if d == dmax:
                    continue
                na = int((loads[dmax] - W[i]).max())
                nb = int((loads[d] + W[i]).max())
                rest = max((int(row_max[r]) for r in range(Dm)
                            if r not in (dmax, d)), default=0)
                tot = max(na, nb, rest)
                if tot < cur and (best is None or tot < best[0]):
                    best = (tot, d)
            if best is not None:
                _, d = best
                loads[dmax] -= W[i]
                loads[d] += W[i]
                device_of[i] = d
                moved = True
                break
        if not moved:
            break
    return Assignment2D(device_of, loads, assignment.method + "+refine2d")


def best_partition_2d(weights_2d: np.ndarray,
                      num_devices: int) -> Assignment2D:
    """Production 2D entry point: LPT and KK on the items' ROW TOTALS
    (each within Graham's bound on the totals, hence on every cell), then
    max-cell local search; keep the best by (makespan, imbalance).

    ``weights_2d [N, Ds]``: per-item per-stripe weights.  Degenerates
    EXACTLY to :func:`best_partition` at ``Ds == 1`` (same device_of),
    which is the seq==1 compatibility contract the property tests pin.
    """
    W = np.asarray(weights_2d, dtype=np.int64)
    if W.ndim != 2:
        raise ValueError(f"weights_2d must be [N, Ds], got {W.shape}")
    N, Ds = W.shape
    Dm = num_devices
    if Ds == 1:
        a = best_partition(W[:, 0], Dm)
        return Assignment2D(a.device_of, a.loads[:, None],
                            a.method + "@seq1")
    totals = W.sum(axis=1)
    seeds = [lpt_partition(totals, Dm)]
    if N <= 1024:
        seeds.append(kk_partition(totals, Dm))
    cands = []
    for s in seeds:
        a2 = Assignment2D(s.device_of.copy(), _loads_2d(W, s.device_of, Dm),
                          s.method + "@2d")
        cands.append(refine_partition_2d(W, a2) if N <= 1024 else a2)
    best = min(cands, key=lambda a: (a.makespan, a.imbalance))
    # the LPT seed is always among the candidates and refinement never
    # raises the max cell, so the winner inherits lpt_bound_2d
    return best


# ---------------------------------------------------------------------------
# Production entry point
# ---------------------------------------------------------------------------

def best_partition(weights: Sequence[int], num_devices: int) -> Assignment:
    """LPT (paper) and KK (beyond-paper), each + refinement; return the best.

    Deterministic.  For small instances (head counts) both run plus local
    search; for large ones (row-mode: thousands of (head, q_blk) atoms) the
    O(n^2/D^2) pairwise-swap refinement is skipped — LPT alone is already
    within one atom of optimal when n >> D.
    """
    w = np.asarray(weights, dtype=np.int64)
    if len(w) > 1024:
        cands = [lpt_partition(w, num_devices)]
    else:
        cands = [
            refine_partition(w, lpt_partition(w, num_devices)),
            refine_partition(w, kk_partition(w, num_devices)),
        ]
    return min(cands, key=lambda a: (a.makespan, a.imbalance))
