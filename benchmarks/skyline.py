"""Paper Fig. 10: latency-accuracy skyline (Pareto frontier).

Sweeps the sparsity knob of each method (budget k for top-k-family methods
and S-HPLB; threshold p for the top-p method) on a hard retrieval task, and
reports (accuracy, modeled latency) points.  Latency = the roofline model of
the method's padded tile grid at the benchmark geometry (hardware-
independent tile counts; the same model as Fig. 9's derived latency)."""
from __future__ import annotations

import json
import os

import numpy as np
import jax.numpy as jnp

from repro.core.budget import maxmin_allocation, uniform_allocation
from repro.core.partition import best_partition, naive_partition
from repro.core.sparsity import HeadSparsityProfile
from repro.core.worklist import blocks_for_budget
from repro.data.ruler import make_batch

BLOCK = 16


def _tiles_per_head(nb, nq):
    n = np.minimum(nb, nq)
    return nq * n - (n - 1) * n // 2


def _method_cost(method: str, profile, k: int, seq: int, H: int,
                 D: int = 4) -> float:
    """Padded-grid tile makespan (per paper: what every device executes)."""
    nq = -(-seq // BLOCK)
    if method == "full":
        tiles = np.full(H, nq * (nq + 1) // 2, np.int64)
        asg = naive_partition(tiles, D, mode="contiguous")
    elif method == "s_hplb":
        b = maxmin_allocation(profile, layer=0, total=H * k, seq_len=seq,
                              block=BLOCK, floor=BLOCK).budgets
        tiles = _tiles_per_head(blocks_for_budget(b, BLOCK), nq)
        asg = best_partition(tiles, D)
    else:  # uniform-budget methods
        b = uniform_allocation(profile, layer=0, k=k, seq_len=seq,
                               block=BLOCK, floor=BLOCK).budgets
        tiles = _tiles_per_head(blocks_for_budget(b, BLOCK), nq)
        asg = naive_partition(tiles, D, mode="contiguous")
    return float(asg.makespan)


def run(out_dir: str, quick: bool = False) -> list[tuple[str, float]]:
    from benchmarks.common import (METHODS, TINY, greedy_answer, token_accuracy,
                                   tiny_lm_params, tiny_lm_profile)
    params, _ = tiny_lm_params()
    profile = tiny_lm_profile(params)

    task = "niah_multikey"   # the hard separating task (paper uses MK2)
    ctx = 192 if quick else 288  # within the training ctx range
    n_examples = 3 if quick else 10
    budgets = [48, 96, 160] if quick else [48, 80, 112, 160, 224]
    sweep_methods = (["streaming", "s_hplb"] if quick
                     else ["streaming", "minference_strided", "quest",
                           "s_hplb"])

    full_cost = _method_cost("full", profile, 0, ctx, TINY.num_heads)

    def accuracy(method: str, k: int) -> float:
        hits = 0
        for i in range(n_examples):
            b = make_batch(task, batch=1, ctx_len=ctx, seed=3000 + i)
            toks = jnp.asarray(b["tokens"])
            a_len = int(b["answer_lens"][0])
            lg, cache = METHODS[method](
                params, toks, TINY, k=k, profile=profile,
                cache_len=toks.shape[1] + a_len + 2)
            pred = greedy_answer(params, TINY, cache, lg, toks.shape[1],
                                 a_len)
            hits += token_accuracy(pred, b["answers"][0][:a_len])
        return hits / n_examples

    points = {"full": [{"k": ctx, "acc": accuracy("full", ctx),
                        "rel_latency": 1.0}]}
    for m in sweep_methods:
        pts = []
        for k in budgets:
            cost_method = "s_hplb" if m == "s_hplb" else "uniform"
            c = _method_cost(cost_method, profile, k, ctx, TINY.num_heads)
            pts.append({"k": k, "acc": accuracy(m, k),
                        "rel_latency": c / full_cost})
            print(f"[skyline] {m} k={k}: acc={pts[-1]['acc']:.2f} "
                  f"lat={pts[-1]['rel_latency']:.3f}", flush=True)
        points[m] = pts

    # Pareto dominance check: does s_hplb sit on the frontier?
    def dominated(p, others):
        return any(o["acc"] >= p["acc"] and o["rel_latency"] <= p[
            "rel_latency"] and (o["acc"] > p["acc"]
                                or o["rel_latency"] < p["rel_latency"])
                   for o in others)

    all_pts = [p for m in sweep_methods for p in points[m]]
    hplb_on_frontier = sum(
        not dominated(p, all_pts) for p in points.get("s_hplb", []))

    rows = [
        ("skyline_points", float(len(all_pts))),
        ("s_hplb_points_on_frontier", float(hplb_on_frontier)),
        ("s_hplb_best_acc", max((p["acc"] for p in points["s_hplb"]),
                                default=0.0)),
        ("full_acc", points["full"][0]["acc"]),
    ]
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "skyline.json"), "w") as f:
        json.dump(points, f, indent=1)
    return rows
