"""GPipe-style pipeline parallelism over ``jax.lax.ppermute`` (optional PP).

Not enabled in the default 40-cell dry-run (TP+DP suffice for memory at the
assigned model sizes — see EXPERIMENTS.md memory analysis); provided for
deployments that need a 4th axis at >8B scale.

Schedule: classic GPipe fill-drain over M microbatches and S stages inside
a shard_map over the ``pipe`` mesh axis.  Each step every stage processes
one microbatch (garbage during fill/drain, masked) and ppermutes its
activation to the next stage; total steps = M + S - 1, bubble fraction
(S-1)/(M+S-1).

    fn = pipeline_apply(stage_fn, mesh, axis="pipe", microbatches=M)
    y = fn(stacked_stage_params, x)       # x [B, ...] -> y [B, ...]

``stage_fn(stage_params, x) -> x`` is the per-stage computation (e.g. a
block of transformer layers); ``stacked_stage_params`` has a leading [S]
dim sharded over ``pipe``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.sharding.compat import shard_map


def pipeline_apply(stage_fn, mesh, *, axis: str = "pipe",
                   microbatches: int | None = None):
    """Build a pipelined apply over the ``axis`` mesh dimension."""
    S = mesh.shape[axis]

    def apply(stage_params, x):
        B = x.shape[0]
        M = microbatches or S
        assert B % M == 0, (B, M)
        mb = B // M

        def island(params_l, x_l):
            # params_l: [1, ...] this stage's params; x_l: FULL batch
            # (replicated input; stage 0 feeds microbatches in, stage S-1
            # collects outputs)
            params_local = jax.tree.map(lambda p: p[0], params_l)
            sid = jax.lax.axis_index(axis)
            xs = x_l.reshape(M, mb, *x_l.shape[1:])
            state = jnp.zeros_like(xs[0])          # stage input register
            outs = jnp.zeros_like(xs)

            def step(carry, t):
                state, outs = carry
                # stage 0 loads microbatch t (if in range)
                feed = jnp.where(t < M, t, 0)
                state = jnp.where(sid == 0, xs[feed], state)
                y = stage_fn(params_local, state)
                # last stage stores its result at slot t - (S - 1)
                slot = jnp.clip(t - (S - 1), 0, M - 1)
                store = jnp.logical_and(sid == S - 1, t >= S - 1)
                outs = jax.lax.dynamic_update_index_in_dim(
                    outs, jnp.where(store, y, outs[slot]), slot, 0)
                # hand activation to the next stage
                state = jax.lax.ppermute(
                    y, axis, [(i, (i + 1) % S) for i in range(S)])
                return (state, outs), None

            (state, outs), _ = jax.lax.scan(
                step, (state, outs), jnp.arange(M + S - 1))
            # only the last stage holds real outputs; psum-broadcast them
            outs = jnp.where(sid == S - 1, outs, 0.0)
            outs = jax.lax.psum(outs, axis)
            return outs.reshape(B, *x.shape[1:])

        pspec = jax.tree.map(lambda _: P(axis), stage_params)
        return shard_map(
            island, mesh=mesh,
            in_specs=(pspec, P()),
            out_specs=P(),
            check_vma=False,
        )(stage_params, x)

    return apply
