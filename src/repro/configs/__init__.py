"""Assigned architecture configs (exact) + reduced smoke variants + shapes."""
from repro.configs.base import ArchSpec
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.configs.registry import ARCHS, cells, get
from repro.configs.inputs import input_specs
