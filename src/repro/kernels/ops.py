"""Public jit'd entry points for the Pallas kernels.

Selects the execution backend: real Pallas lowering on TPU, ``interpret=True``
elsewhere (this container is CPU-only; interpret mode executes the kernel
body in Python and is the validation target).  Models and the serving engine
call through this module, never the kernels directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.attention.worklist_jnp import (
    packed_decode_attention as _packed_decode_ref,
    packed_decode_attention_paged as _packed_decode_paged_ref,
)
from repro.kernels.flash_attn import flash_attention as _flash
from repro.kernels.flash_decode import (
    decode_items_from_ids,
    flash_decode_kernel as _flash_decode_kernel,
    flash_decode_paged_kernel as _flash_decode_paged_kernel,
    flash_decode_paged_reference as _flash_decode_paged_ref,
    flash_decode_reference as _flash_decode_ref,
    merge_partials,
)
from repro.kernels.sparse_prefill import sparse_prefill_attention as _sparse_prefill
from repro.kernels.sparse_decode import (
    DecodeWorkList,
    build_decode_worklist,
    sparse_decode_attention as _sparse_decode,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, block_q=128, block_kv=128,
                    scale=None, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _flash(q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
                  scale=scale, interpret=interpret)


def sparse_prefill(q, k, v, items, *, block_q=128, block_kv=128, scale=None,
                   interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _sparse_prefill(q, k, v, jnp.asarray(items), block_q=block_q,
                           block_kv=block_kv, scale=scale,
                           interpret=interpret)


def sparse_decode(q, k_cache, v_cache, items, *, cache_len, block_kv=128,
                  scale=None, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _sparse_decode(q, k_cache, v_cache, jnp.asarray(items),
                          cache_len=cache_len, block_kv=block_kv, scale=scale,
                          interpret=interpret)


def flash_decode(q, k_cache, v_cache, block_ids, pos, *, block_kv=128,
                 scale=None, window=None, partials=False, use_kernel=None,
                 interpret=None, k_scales=None, v_scales=None):
    """Fused budgeted flash-decode: stream only the selected KV blocks.

    q ``[B, H, 1, D]`` (serving layout — GQA grouping happens here);
    caches ``[B, Hkv, Smax, D]``; ``block_ids [B, Hkv, nb]`` int32 selected
    cache blocks (-1 pad, trailing); ``pos [B]`` per-slot last position.
    With a quantized cache (DESIGN.md §2.12) pass ``k_scales``/``v_scales``
    ``[B, Hkv, Smax/block_kv]`` f32 — dequantization fuses into the
    executor (post-dot rescale), no f32 cache copy is ever materialized.

    ``partials=True`` returns ``(out [B,H,1,D], m, l [B,Hkv,G])`` for the
    flash-decoding cross-shard merge; otherwise just ``out``.  On TPU the
    Pallas kernel runs; elsewhere the jnp reference executes the same
    zero-copy access pattern (scan + dynamic_slice, no dense gather).
    """
    B, H, _, dh = q.shape
    hkv = k_cache.shape[1]
    G = H // hkv
    qg = q.reshape(B, hkv, G, dh)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        if interpret is None:
            interpret = not _on_tpu()
        items = decode_items_from_ids(jnp.asarray(block_ids))
        out, m, l = _flash_decode_kernel(
            qg, k_cache, v_cache, items, jnp.asarray(pos),
            block_kv=block_kv, scale=scale, window=window,
            interpret=interpret, k_scales=k_scales, v_scales=v_scales)
    else:
        out, m, l = _flash_decode_ref(
            qg, k_cache, v_cache, jnp.asarray(block_ids), jnp.asarray(pos),
            block_kv=block_kv, scale=scale, window=window,
            k_scales=k_scales, v_scales=v_scales)
    out = out.reshape(B, H, 1, dh)
    if partials:
        return out, m, l        # out is f32 — merge-able without requantizing
    return out.astype(q.dtype)


def flash_decode_paged(q, k_pool, v_pool, block_ids, table, pos, *,
                       block_kv=128, scale=None, window=None, partials=False,
                       use_kernel=None, interpret=None, k_scales=None,
                       v_scales=None):
    """Paged fused flash-decode: stream selected blocks from the pool.

    q ``[B, H, 1, D]`` (serving layout — GQA grouping happens here);
    pools ``[N, Hkv, block_kv, D]``; ``block_ids [B, Hkv, nb]`` int32
    LOGICAL selected blocks (-1 pad, trailing); ``table [B, T]`` int32
    logical -> pool-global translation (-1 = unmapped, masked); ``pos [B]``
    per-slot last position.  With a quantized pool pass ``k_scales``/
    ``v_scales`` ``[N, Hkv]`` f32 (PHYSICAL block index — the scale travels
    with its pool block through the same table indirection).  Same
    returns/partials contract as :func:`flash_decode`; on TPU the
    scalar-prefetch table-indirection kernel runs, elsewhere the jnp
    reference with the identical zero-copy access pattern.
    """
    B, H, _, dh = q.shape
    hkv = k_pool.shape[1]
    G = H // hkv
    qg = q.reshape(B, hkv, G, dh)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        if interpret is None:
            interpret = not _on_tpu()
        items = decode_items_from_ids(jnp.asarray(block_ids))
        out, m, l = _flash_decode_paged_kernel(
            qg, k_pool, v_pool, items, jnp.asarray(table), jnp.asarray(pos),
            block_kv=block_kv, scale=scale, window=window,
            interpret=interpret, k_scales=k_scales, v_scales=v_scales)
    else:
        out, m, l = _flash_decode_paged_ref(
            qg, k_pool, v_pool, jnp.asarray(block_ids), jnp.asarray(table),
            jnp.asarray(pos), block_kv=block_kv, scale=scale, window=window,
            k_scales=k_scales, v_scales=v_scales)
    out = out.reshape(B, H, 1, dh)
    if partials:
        return out, m, l        # out is f32 — merge-able without requantizing
    return out.astype(q.dtype)


def flash_decode_packed(q, k_cache, v_cache, items, pos, *, block_kv=128,
                        scale=None, window=None, partials=False,
                        use_kernel=None, interpret=None, k_scales=None,
                        v_scales=None):
    """Cost-packed ragged flash-decode (DESIGN.md §2.8).

    q ``[B, H, 1, D]`` (serving layout — GQA grouping happens here);
    caches ``[B, Hkv, Smax, D]``; ``items [L, DEC_FIELDS]`` int32 packed
    decode worklist (one (row, kv_head, kv_block) tile per row, runs
    contiguous, replicate-last padding at valid=0); ``pos [B]`` per-slot
    last position.  The grid/scan length is the PACKED item count — decode
    cost scales with ``mean_h b_h`` instead of ``Hkv x max_h b_h x B``.
    On TPU the Pallas kernel consumes the table directly; elsewhere the
    bitwise jnp twin executes the same ragged grid.  Same returns/partials
    contract as :func:`flash_decode`.
    """
    B, H, _, dh = q.shape
    hkv = k_cache.shape[1]
    G = H // hkv
    qg = q.reshape(B, hkv, G, dh)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        if interpret is None:
            interpret = not _on_tpu()
        out, m, l = _flash_decode_kernel(
            qg, k_cache, v_cache, jnp.asarray(items), jnp.asarray(pos),
            block_kv=block_kv, scale=scale, window=window,
            interpret=interpret, k_scales=k_scales, v_scales=v_scales)
    else:
        out, m, l = _packed_decode_ref(
            qg, k_cache, v_cache, jnp.asarray(items), jnp.asarray(pos),
            block_kv=block_kv, scale=scale, window=window,
            k_scales=k_scales, v_scales=v_scales)
    out = out.reshape(B, H, 1, dh)
    if partials:
        return out, m, l
    return out.astype(q.dtype)


def flash_decode_packed_paged(q, k_pool, v_pool, items, table, pos, *,
                              block_kv=128, scale=None, window=None,
                              partials=False, use_kernel=None,
                              interpret=None, k_scales=None, v_scales=None):
    """Paged twin of :func:`flash_decode_packed`: the packed items' LOGICAL
    kv blocks translate to pool blocks through ``table [B, T]`` (-1 =
    unmapped, masked); same contract otherwise."""
    B, H, _, dh = q.shape
    hkv = k_pool.shape[1]
    G = H // hkv
    qg = q.reshape(B, hkv, G, dh)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        if interpret is None:
            interpret = not _on_tpu()
        out, m, l = _flash_decode_paged_kernel(
            qg, k_pool, v_pool, jnp.asarray(items), jnp.asarray(table),
            jnp.asarray(pos), block_kv=block_kv, scale=scale, window=window,
            interpret=interpret, k_scales=k_scales, v_scales=v_scales)
    else:
        out, m, l = _packed_decode_paged_ref(
            qg, k_pool, v_pool, jnp.asarray(items), jnp.asarray(table),
            jnp.asarray(pos), block_kv=block_kv, scale=scale, window=window,
            k_scales=k_scales, v_scales=v_scales)
    out = out.reshape(B, H, 1, dh)
    if partials:
        return out, m, l
    return out.astype(q.dtype)


__all__ = [
    "flash_attention",
    "sparse_prefill",
    "sparse_decode",
    "flash_decode",
    "flash_decode_paged",
    "flash_decode_packed",
    "flash_decode_packed_paged",
    "merge_partials",
    "DecodeWorkList",
    "build_decode_worklist",
]
