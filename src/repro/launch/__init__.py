"""Launchers: mesh factory, dry-run driver, train/serve entry points.

NOTE: do NOT import repro.launch.dryrun from library code — it sets
XLA_FLAGS at import time (dry-run only).
"""
from repro.launch.mesh import make_host_mesh, make_production_mesh
