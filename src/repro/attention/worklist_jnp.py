"""Pure-jnp work-list attention — the portable twin of the Pallas kernels.

The models and the dry-run path cannot lower Mosaic TPU kernels on the CPU
container, so the same flattened work-list execution model (DESIGN.md §2.2)
is provided as a ``lax.scan`` over items with dynamic slices.  Properties:

- HLO size is O(1) in sequence length (a while loop over the item list) —
  a 500k-context program lowers as compactly as a 4k one;
- FLOPs are EXACT: only selected (head, q_blk, kv_blk) tiles are computed —
  ``cost_analysis`` of the lowered step reflects the true sparse compute,
  which is what the roofline analysis reads;
- it is differentiable (scan + dynamic_update_slice), so the same path
  serves training with causal work-lists;
- semantics match ``kernels.sparse_prefill`` bit-for-bit in f32.

``causal_items`` builds the dense-causal work-list (used for baseline/
training attention); sparse lists come from ``repro.core.worklist``.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.worklist import (
    D_BATCH,
    D_FIRST,
    D_KVBLK,
    D_KVHEAD,
    D_LAST,
    D_VALID,
    F_FIRST,
    F_HEAD,
    F_KVBLK,
    F_KVHEAD,
    F_LAST,
    F_QBLK,
    F_VALID,
    ITEM_FIELDS,
)

NEG_INF = -1e30


def causal_items(num_heads: int, nq: int, kv_of_head: np.ndarray | None = None,
                 ) -> np.ndarray:
    """Full-causal work-list: every (h, qb, kb <= qb) tile.  [L, 7] int32."""
    if kv_of_head is None:
        kv_of_head = np.arange(num_heads)
    rows = []
    for h in range(num_heads):
        for qb in range(nq):
            for kb in range(qb + 1):
                rows.append((h, qb, kb, int(kb == 0), int(kb == qb), 1,
                             int(kv_of_head[h])))
    return np.asarray(rows, dtype=np.int32)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "scale"))
def worklist_attention(
    q: jnp.ndarray,       # [H, Sq, D]
    k: jnp.ndarray,       # [Hkv, Skv, D]
    v: jnp.ndarray,
    items: jnp.ndarray,   # [L, ITEM_FIELDS] int32
    *,
    block_q: int = 128,
    block_kv: int = 128,
    scale: float | None = None,
    q_offset: jnp.ndarray | int | None = None,
    kv_len: jnp.ndarray | int | None = None,
):
    """Execute a work-list with a single lax.scan (one device's list).

    Mirrors ``kernels.sparse_prefill.sparse_prefill_attention``; (head, q_blk)
    tiles with no items yield zero rows.

    ``q_offset`` / ``kv_len`` support chunked prefill: queries live at global
    positions ``q_offset + i`` (item q_blk stays chunk-local) and attend kv
    positions ``< kv_len`` of a cache longer than the chunk.  Both are traced
    scalars — one compile serves every chunk offset.  ``None`` (the default)
    is the classic whole-sequence behavior (offset 0, kv_len = Skv).
    """
    hq, sq, dh = q.shape
    hkv, skv, _ = k.shape
    scale_v = (dh ** -0.5) if scale is None else scale
    pad_q = (-sq) % block_q
    pad_kv = (-skv) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))).astype(jnp.float32)
    kp = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0))).astype(jnp.float32)
    vp = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0))).astype(jnp.float32)
    sqp = qp.shape[1]

    out0 = jnp.zeros((hq, sqp, dh), jnp.float32)
    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)

    def step(carry, it):
        out, acc, m, l = carry
        head, qblk, kvblk = it[F_HEAD], it[F_QBLK], it[F_KVBLK]
        kvh = it[F_KVHEAD]
        first = it[F_FIRST] == 1
        last = it[F_LAST] == 1
        valid = it[F_VALID] == 1

        acc = jnp.where(first, jnp.zeros_like(acc), acc)
        m = jnp.where(first, jnp.full_like(m, -jnp.inf), m)
        l = jnp.where(first, jnp.zeros_like(l), l)

        qt = jax.lax.dynamic_slice(
            qp, (head, qblk * block_q, 0), (1, block_q, dh))[0]
        kt = jax.lax.dynamic_slice(
            kp, (kvh, kvblk * block_kv, 0), (1, block_kv, dh))[0]
        vt = jax.lax.dynamic_slice(
            vp, (kvh, kvblk * block_kv, 0), (1, block_kv, dh))[0]
        s = (qt @ kt.T) * scale_v
        qpos = qblk * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kvblk * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos_g = qpos if q_offset is None else qpos + q_offset
        klim = skv if kv_len is None else jnp.minimum(
            jnp.asarray(kv_len, jnp.int32), skv)
        mask = (kpos <= qpos_g) & (kpos < klim) & (qpos < sq) & valid
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        acc_new = acc * alpha + p @ vt
        # no-op the accumulator update on invalid (padding) items
        acc = jnp.where(valid, acc_new, acc)
        l = jnp.where(valid, l_new, l)
        m = jnp.where(valid, m_new, m)

        write = valid & last
        norm = acc / jnp.maximum(l, 1e-30)
        norm = jnp.where(l > 0.0, norm, 0.0)
        cur = jax.lax.dynamic_slice(
            out, (head, qblk * block_q, 0), (1, block_q, dh))[0]
        tile = jnp.where(write, norm, cur)
        out = jax.lax.dynamic_update_slice(
            out, tile[None], (head, qblk * block_q, 0))
        return (out, acc, m, l), None

    (out, _, _, _), _ = jax.lax.scan(step, (out0, acc0, m0, l0), items)
    return out[:, :sq, :].astype(q.dtype)


def batched_worklist_attention(q, k, v, items, **kw):
    """vmap over a leading batch dim; items shared across the batch."""
    fn = functools.partial(worklist_attention, **kw)
    return jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv, items))(q, k, v)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "scale"))
def worklist_attention_paged(
    q: jnp.ndarray,       # [H, Sq, D]
    k_pool: jnp.ndarray,  # [N, Hkv, block_kv, D]  device block pool
    v_pool: jnp.ndarray,
    items: jnp.ndarray,   # [L, ITEM_FIELDS] int32 (kv_blk LOGICAL)
    table: jnp.ndarray,   # [T] int32 logical kv block -> pool block (-1)
    *,
    block_q: int = 128,
    block_kv: int = 128,
    scale: float | None = None,
    q_offset: jnp.ndarray | int | None = None,
    kv_len: jnp.ndarray | int | None = None,
    k_scales: jnp.ndarray | None = None,   # [N, Hkv] f32, PHYSICAL index
    v_scales: jnp.ndarray | None = None,
):
    """Paged twin of :func:`worklist_attention` (DESIGN.md §2.7): the K/V
    tiles come from a device block POOL through the sequence's block table
    instead of a contiguous per-sequence cache.  Item ``kv_blk`` stays in
    the LOGICAL namespace (positions and masks derive from it); only the
    slice ADDRESS is table-indirected, so tile values, masks, and the
    accumulation order — hence the bit pattern of the output — match the
    contiguous executor on equal cache contents.  ``kv_len`` masks
    positions past the resident prefix, which also guarantees every
    contributing logical block is mapped; unmapped (-1) entries are
    clamped to pool block 0 and masked out.  With a quantized pool
    (§2.12) pass ``k_scales``/``v_scales [N, Hkv]`` f32 — the chunked
    prefill's reads of PAST resident blocks dequantize post-dot, same as
    the decode executors.
    """
    hq, sq, dh = q.shape
    assert k_pool.shape[2] == block_kv, "pool block size != block_kv"
    scale_v = (dh ** -0.5) if scale is None else scale
    quantized = k_scales is not None
    pad_q = (-sq) % block_q
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0))).astype(jnp.float32)
    sqp = qp.shape[1]
    tbl = table.astype(jnp.int32)
    klim_default = tbl.shape[0] * block_kv
    if quantized:
        ksf = k_scales.astype(jnp.float32)
        vsf = v_scales.astype(jnp.float32)

    out0 = jnp.zeros((hq, sqp, dh), jnp.float32)
    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((block_q, 1), jnp.float32)

    def step(carry, it):
        out, acc, m, l = carry
        head, qblk, kvblk = it[F_HEAD], it[F_QBLK], it[F_KVBLK]
        kvh = it[F_KVHEAD]
        first = it[F_FIRST] == 1
        last = it[F_LAST] == 1
        valid = it[F_VALID] == 1

        acc = jnp.where(first, jnp.zeros_like(acc), acc)
        m = jnp.where(first, jnp.full_like(m, -jnp.inf), m)
        l = jnp.where(first, jnp.zeros_like(l), l)

        phys = tbl[jnp.maximum(kvblk, 0)]
        mapped = phys >= 0
        safe = jnp.maximum(phys, 0)
        qt = jax.lax.dynamic_slice(
            qp, (head, qblk * block_q, 0), (1, block_q, dh))[0]
        kt = jax.lax.dynamic_slice(
            k_pool, (safe, kvh, 0, 0), (1, 1, block_kv, dh))[0, 0]
        vt = jax.lax.dynamic_slice(
            v_pool, (safe, kvh, 0, 0), (1, 1, block_kv, dh))[0, 0]
        if not quantized:
            kt = kt.astype(jnp.float32)
            vt = vt.astype(jnp.float32)
        # mixed f32 x codes dot on the quantized path; the raw code tile
        # feeds the dot (no convert to hoist), scale applied to the logits
        s = jax.lax.dot_general(
            qt, kt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale_v
        if quantized:
            s = s * ksf[safe, kvh]
        qpos = qblk * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = kvblk * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qpos_g = qpos if q_offset is None else qpos + q_offset
        klim = klim_default if kv_len is None else jnp.minimum(
            jnp.asarray(kv_len, jnp.int32), klim_default)
        mask = ((kpos <= qpos_g) & (kpos < klim) & (qpos < sq)
                & valid & mapped)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, vt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        if quantized:
            pv = pv * vsf[safe, kvh]
        acc_new = acc * alpha + pv
        # no-op the accumulator update on invalid (padding) items
        acc = jnp.where(valid, acc_new, acc)
        l = jnp.where(valid, l_new, l)
        m = jnp.where(valid, m_new, m)

        write = valid & last
        norm = acc / jnp.maximum(l, 1e-30)
        norm = jnp.where(l > 0.0, norm, 0.0)
        cur = jax.lax.dynamic_slice(
            out, (head, qblk * block_q, 0), (1, block_q, dh))[0]
        tile = jnp.where(write, norm, cur)
        out = jax.lax.dynamic_update_slice(
            out, tile[None], (head, qblk * block_q, 0))
        return (out, acc, m, l), None

    (out, _, _, _), _ = jax.lax.scan(step, (out0, acc0, m0, l0), items)
    return out[:, :sq, :].astype(q.dtype)


# ---------------------------------------------------------------------------
# Cost-packed ragged decode executors (DESIGN.md §2.8)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block_kv", "scale", "window"))
def packed_decode_attention(
    q: jnp.ndarray,          # [B, Hkv, G, D]  (GQA-grouped query rows)
    k_cache: jnp.ndarray,    # [B, Hkv, Smax, D]
    v_cache: jnp.ndarray,
    items: jnp.ndarray,      # [L, DEC_FIELDS] int32 packed decode worklist
    pos: jnp.ndarray,        # [B] int32 per-slot last position (inclusive)
    *,
    block_kv: int = 128,
    scale: float | None = None,
    window: int | None = None,
    k_scales: jnp.ndarray | None = None,   # [B, Hkv, Smax/block_kv] f32
    v_scales: jnp.ndarray | None = None,
):
    """Execute a cost-packed decode worklist with one ``lax.scan``.

    The portable twin of running ``kernels.flash_decode_kernel`` over a
    packed item table: grid length == the PACKED list length (total real
    items rounded to the compile bucket), not ``B x Hkv x max-budget``.
    Per (row, kv head) run the block-update arithmetic replicates
    :func:`repro.kernels.flash_decode.flash_decode_reference` op for op —
    same tiles, same accumulation order — so the two paths produce
    BITWISE-identical outputs (hence identical greedy tokens) on equal
    selections.  Returns the same ``(out f32, m, l)`` partials contract.
    ``k_scales``/``v_scales`` enable the quantized-cache path (§2.12):
    per-(slot, kv-head, block) dequant scales applied AFTER the dots.
    """
    B, hkv, G, dh = q.shape
    smax = k_cache.shape[2]
    scale_v = float(dh ** -0.5) if scale is None else float(scale)
    quantized = k_scales is not None
    pad_s = (-smax) % block_kv
    kp = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    vp = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    if quantized:
        pad_b = (smax + pad_s) // block_kv - k_scales.shape[2]
        ksp = jnp.pad(k_scales.astype(jnp.float32),
                      ((0, 0), (0, 0), (0, pad_b)))
        vsp = jnp.pad(v_scales.astype(jnp.float32),
                      ((0, 0), (0, 0), (0, pad_b)))
    qc = q.astype(jnp.float32 if quantized else k_cache.dtype)
    pos_i = jnp.asarray(pos, jnp.int32)

    out0 = jnp.zeros((B, hkv, G, dh), jnp.float32)
    m_out0 = jnp.full((B, hkv, G), NEG_INF, jnp.float32)
    l_out0 = jnp.zeros((B, hkv, G), jnp.float32)
    acc0 = jnp.zeros((G, dh), jnp.float32)
    m0 = jnp.full((G, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G, 1), jnp.float32)

    def step(carry, it):
        out, m_out, l_out, acc, m, l = carry
        b, h, blk = it[D_BATCH], it[D_KVHEAD], it[D_KVBLK]
        first = it[D_FIRST] == 1
        last = it[D_LAST] == 1
        ok = it[D_VALID] == 1

        acc = jnp.where(first, jnp.zeros_like(acc), acc)
        m = jnp.where(first, jnp.full_like(m, NEG_INF), m)
        l = jnp.where(first, jnp.zeros_like(l), l)

        qh = jax.lax.dynamic_slice(qc, (b, h, 0, 0), (1, 1, G, dh))[0, 0]
        kt = jax.lax.dynamic_slice(
            kp, (b, h, blk * block_kv, 0), (1, 1, block_kv, dh))[0, 0]
        vt = jax.lax.dynamic_slice(
            vp, (b, h, blk * block_kv, 0), (1, 1, block_kv, dh))[0, 0]
        p = pos_i[b]
        # block-update arithmetic == flash_decode_reference, verbatim
        s = jax.lax.dot_general(
            qh, kt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale_v   # [G, blk]
        if quantized:
            s = s * ksp[b, h, blk]
        kpos = blk * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = (kpos <= p) & ok
        if window is not None:
            mask &= kpos > p - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        pr = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + pr.sum(axis=-1, keepdims=True)
        # f32 p.V dot (see flash_decode_reference): keeps the striped-merge
        # path bit-compatible with single-pass math
        if quantized:
            # mixed f32 x codes dot, post-dot V dequant — no vt convert
            pv = jax.lax.dot_general(
                pr, vt, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32) * vsp[b, h, blk]
        else:
            pv = jax.lax.dot_general(
                pr, vt.astype(jnp.float32), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        acc_new = acc * alpha + pv
        acc = jnp.where(ok, acc_new, acc)
        m = jnp.where(ok, m_new, m)
        l = jnp.where(ok, l_new, l)

        # finalize on `last` alone (matching the Pallas kernel's
        # @pl.when(last)): the PADDED table sets is_last on the run's final
        # stride row even when that row is invalid padding; packed tables
        # only carry last=1 on real items, so both layouts write correctly
        write = last
        norm = acc / jnp.maximum(l, 1e-30)
        norm = jnp.where(l > 0.0, norm, 0.0)
        cur = jax.lax.dynamic_slice(out, (b, h, 0, 0), (1, 1, G, dh))[0, 0]
        out = jax.lax.dynamic_update_slice(
            out, jnp.where(write, norm, cur)[None, None], (b, h, 0, 0))
        cur_m = jax.lax.dynamic_slice(m_out, (b, h, 0), (1, 1, G))[0, 0]
        m_out = jax.lax.dynamic_update_slice(
            m_out, jnp.where(write, m[:, 0], cur_m)[None, None], (b, h, 0))
        cur_l = jax.lax.dynamic_slice(l_out, (b, h, 0), (1, 1, G))[0, 0]
        l_out = jax.lax.dynamic_update_slice(
            l_out, jnp.where(write, l[:, 0], cur_l)[None, None], (b, h, 0))
        return (out, m_out, l_out, acc, m, l), None

    (out, m_out, l_out, _, _, _), _ = jax.lax.scan(
        step, (out0, m_out0, l_out0, acc0, m0, l0), items)
    return out, m_out, l_out


@functools.partial(jax.jit, static_argnames=("block_kv", "scale", "window"))
def packed_decode_attention_paged(
    q: jnp.ndarray,          # [B, Hkv, G, D]
    k_pool: jnp.ndarray,     # [N, Hkv, block_kv, D]  device block pool
    v_pool: jnp.ndarray,
    items: jnp.ndarray,      # [L, DEC_FIELDS] int32, D_KVBLK LOGICAL
    table: jnp.ndarray,      # [B, T] int32 logical -> pool block (-1)
    pos: jnp.ndarray,        # [B] int32 per-slot last position (inclusive)
    *,
    block_kv: int = 128,
    scale: float | None = None,
    window: int | None = None,
    k_scales: jnp.ndarray | None = None,   # [N, Hkv] f32, PHYSICAL index
    v_scales: jnp.ndarray | None = None,
):
    """Paged twin of :func:`packed_decode_attention`: tiles come from the
    block POOL through the per-slot table; item kv blocks stay LOGICAL
    (positions/masks derive from them), only the slice address is
    indirected; unmapped entries are masked.  Per-run arithmetic replicates
    ``flash_decode_paged_reference`` op for op (bitwise on equal
    selections); same ``(out f32, m, l)`` returns.  ``k_scales``/
    ``v_scales [N, Hkv]`` f32 (physical-block-indexed) enable the
    quantized-pool path (§2.12): post-dot dequant, no f32 pool copy.
    """
    B, hkv, G, dh = q.shape
    assert k_pool.shape[2] == block_kv, "pool block size != block_kv"
    scale_v = float(dh ** -0.5) if scale is None else float(scale)
    quantized = k_scales is not None
    tbl = jnp.asarray(table, jnp.int32)
    if quantized:
        ksf = k_scales.astype(jnp.float32)
        vsf = v_scales.astype(jnp.float32)
    qc = q.astype(jnp.float32 if quantized else k_pool.dtype)
    pos_i = jnp.asarray(pos, jnp.int32)

    out0 = jnp.zeros((B, hkv, G, dh), jnp.float32)
    m_out0 = jnp.full((B, hkv, G), NEG_INF, jnp.float32)
    l_out0 = jnp.zeros((B, hkv, G), jnp.float32)
    acc0 = jnp.zeros((G, dh), jnp.float32)
    m0 = jnp.full((G, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G, 1), jnp.float32)

    def step(carry, it):
        out, m_out, l_out, acc, m, l = carry
        b, h, blk = it[D_BATCH], it[D_KVHEAD], it[D_KVBLK]
        first = it[D_FIRST] == 1
        last = it[D_LAST] == 1
        valid = it[D_VALID] == 1

        acc = jnp.where(first, jnp.zeros_like(acc), acc)
        m = jnp.where(first, jnp.full_like(m, NEG_INF), m)
        l = jnp.where(first, jnp.zeros_like(l), l)

        phys = tbl[b, blk]
        ok = valid & (phys >= 0)
        safe = jnp.maximum(phys, 0)
        qh = jax.lax.dynamic_slice(qc, (b, h, 0, 0), (1, 1, G, dh))[0, 0]
        kt = jax.lax.dynamic_slice(
            k_pool, (safe, h, 0, 0), (1, 1, block_kv, dh))[0, 0]
        vt = jax.lax.dynamic_slice(
            v_pool, (safe, h, 0, 0), (1, 1, block_kv, dh))[0, 0]
        p = pos_i[b]
        s = jax.lax.dot_general(
            qh, kt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale_v
        if quantized:
            s = s * ksf[safe, h]
        kpos = blk * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = (kpos <= p) & ok
        if window is not None:
            mask &= kpos > p - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1, keepdims=True))
        pr = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + pr.sum(axis=-1, keepdims=True)
        # f32 p.V dot (see flash_decode_reference): keeps the striped-merge
        # path bit-compatible with single-pass math
        if quantized:
            pv = jax.lax.dot_general(
                pr, vt, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32) * vsf[safe, h]
        else:
            pv = jax.lax.dot_general(
                pr, vt.astype(jnp.float32), (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
        acc_new = acc * alpha + pv
        acc = jnp.where(ok, acc_new, acc)
        m = jnp.where(ok, m_new, m)
        l = jnp.where(ok, l_new, l)

        write = last  # kernel-parity: finalize on `last` alone (see above)
        norm = acc / jnp.maximum(l, 1e-30)
        norm = jnp.where(l > 0.0, norm, 0.0)
        cur = jax.lax.dynamic_slice(out, (b, h, 0, 0), (1, 1, G, dh))[0, 0]
        out = jax.lax.dynamic_update_slice(
            out, jnp.where(write, norm, cur)[None, None], (b, h, 0, 0))
        cur_m = jax.lax.dynamic_slice(m_out, (b, h, 0), (1, 1, G))[0, 0]
        m_out = jax.lax.dynamic_update_slice(
            m_out, jnp.where(write, m[:, 0], cur_m)[None, None], (b, h, 0))
        cur_l = jax.lax.dynamic_slice(l_out, (b, h, 0), (1, 1, G))[0, 0]
        l_out = jax.lax.dynamic_update_slice(
            l_out, jnp.where(write, l[:, 0], cur_l)[None, None], (b, h, 0))
        return (out, m_out, l_out, acc, m, l), None

    (out, m_out, l_out, _, _, _), _ = jax.lax.scan(
        step, (out0, m_out0, l_out0, acc0, m0, l0), items)
    return out, m_out, l_out
