"""Public jit'd entry points for the Pallas kernels.

Selects the execution backend: real Pallas lowering on TPU, ``interpret=True``
elsewhere (this container is CPU-only; interpret mode executes the kernel
body in Python and is the validation target).  Models and the serving engine
call through this module, never the kernels directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn import flash_attention as _flash
from repro.kernels.sparse_prefill import sparse_prefill_attention as _sparse_prefill
from repro.kernels.sparse_decode import (
    DecodeWorkList,
    build_decode_worklist,
    sparse_decode_attention as _sparse_decode,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, block_q=128, block_kv=128,
                    scale=None, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _flash(q, k, v, causal=causal, block_q=block_q, block_kv=block_kv,
                  scale=scale, interpret=interpret)


def sparse_prefill(q, k, v, items, *, block_q=128, block_kv=128, scale=None,
                   interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _sparse_prefill(q, k, v, jnp.asarray(items), block_q=block_q,
                           block_kv=block_kv, scale=scale,
                           interpret=interpret)


def sparse_decode(q, k_cache, v_cache, items, *, cache_len, block_kv=128,
                  scale=None, interpret=None):
    if interpret is None:
        interpret = not _on_tpu()
    return _sparse_decode(q, k_cache, v_cache, jnp.asarray(items),
                          cache_len=cache_len, block_kv=block_kv, scale=scale,
                          interpret=interpret)


__all__ = [
    "flash_attention",
    "sparse_prefill",
    "sparse_decode",
    "DecodeWorkList",
    "build_decode_worklist",
]
