"""Adaptive head budget allocation (paper §3.2).

Given per-head recovery curves (offline profile) and a *global* token budget
``K_total = num_heads * k`` (what a uniform top-k scheme would spend), assign
each head a budget ``b_h`` with ``sum(b_h) == K_total`` so that the minimum
per-head recovery ratio is maximized — the paper's **max–min budget
shifting**.

Implementations
---------------
- :func:`uniform_allocation`        — the top-k baseline (every head gets k).
- :func:`topp_allocation`           — the top-p baseline's cost: per-head
                                      budget to reach recovery ``p``
                                      (no global budget constraint).
- :func:`maxmin_allocation`         — the paper's iterative transfer
                                      algorithm (Fig. 7), faithful: move one
                                      quantum from the highest-recovery head
                                      to the lowest-recovery head until no
                                      benefit or all donors at the floor.
- :func:`waterfill_allocation`      — beyond-paper exact solver: the max-min
                                      optimum has a water-filling structure
                                      (all non-floored heads sit at equal
                                      recovery level r*), found by bisection
                                      on r*.  Used both as a production
                                      allocator and as the test oracle for
                                      the greedy.

Budgets are in **tokens**, quantized to ``block`` multiples (TPU adaptation:
KV selection is block-granular, see DESIGN.md §2.5), floored at ``floor``
tokens (paper: 128 — exactly one 128-token block) and capped at ``seq_len``.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core.sparsity import HeadSparsityProfile


@dataclasses.dataclass
class AllocationResult:
    """Output of a budget allocator.

    budgets:    ``[H]`` int tokens per head (block-quantized).
    recovery:   ``[H]`` predicted recovery ratio at those budgets.
    iterations: number of transfer iterations (greedy) / bisection steps.
    total:      sum of budgets actually allocated.
    """

    budgets: np.ndarray
    recovery: np.ndarray
    iterations: int
    total: int

    @property
    def min_recovery(self) -> float:
        return float(self.recovery.min())

    @property
    def mean_recovery(self) -> float:
        return float(self.recovery.mean())


def _as_curves(profile: HeadSparsityProfile | tuple, layer: int | None):
    """Accept a profile (+layer) or a raw ``(curves[H,G], grid[G])`` tuple."""
    if isinstance(profile, HeadSparsityProfile):
        assert layer is not None, "pass layer= when giving a HeadSparsityProfile"
        return profile.curves[layer], profile.grid
    curves, grid = profile
    return np.asarray(curves, dtype=np.float64), np.asarray(grid, dtype=np.float64)


def _recovery_tokens(curves: np.ndarray, grid: np.ndarray, seq_len: int,
                     budgets: np.ndarray) -> np.ndarray:
    """Vectorized per-head recovery at token budgets (interp on frac grid)."""
    fracs = np.clip(budgets / float(seq_len), 0.0, 1.0)
    out = np.empty(curves.shape[0])
    for h in range(curves.shape[0]):
        out[h] = np.interp(fracs[h], grid, curves[h])
    return out


def _quantize(budgets: np.ndarray, block: int, floor: int, seq_len: int) -> np.ndarray:
    b = np.ceil(np.asarray(budgets, dtype=np.float64) / block) * block
    return np.clip(b, floor, seq_len).astype(np.int64)


# ---------------------------------------------------------------------------
# Baselines
# ---------------------------------------------------------------------------

def uniform_allocation(
    profile, *, layer: int | None = None, k: int, seq_len: int,
    block: int = 128, floor: int = 128,
) -> AllocationResult:
    """Top-k baseline: identical budget ``k`` on every head (paper §2.3)."""
    curves, grid = _as_curves(profile, layer)
    H = curves.shape[0]
    budgets = _quantize(np.full(H, k), block, floor, seq_len)
    rec = _recovery_tokens(curves, grid, seq_len, budgets)
    return AllocationResult(budgets, rec, 0, int(budgets.sum()))


def topp_allocation(
    profile, *, layer: int | None = None, p: float, seq_len: int,
    block: int = 128, floor: int = 128,
) -> AllocationResult:
    """Top-p baseline's *budget cost*: per-head tokens to reach recovery p.

    This is the idealized cost of XAttention-style methods — note it has no
    global budget constraint, so its total varies per layer (the source of
    the load imbalance in paper Fig. 4).
    """
    curves, grid = _as_curves(profile, layer)
    H = curves.shape[0]
    budgets = np.empty(H)
    for h in range(H):
        budgets[h] = np.interp(
            p, curves[h], grid, left=grid[0], right=1.0
        ) * seq_len
    budgets = _quantize(budgets, block, floor, seq_len)
    rec = _recovery_tokens(curves, grid, seq_len, budgets)
    return AllocationResult(budgets, rec, 0, int(budgets.sum()))


# ---------------------------------------------------------------------------
# Paper: iterative max-min transfer (Fig. 7)
# ---------------------------------------------------------------------------

def maxmin_allocation(
    profile, *, layer: int | None = None, total: int, seq_len: int,
    block: int = 128, floor: int = 128, max_iters: int = 100_000,
    init_budgets: np.ndarray | None = None,
) -> AllocationResult:
    """The paper's iterative max-min budget shifting (§3.2, Fig. 7).

    Start from the uniform split of ``total``; repeatedly move one ``block``
    quantum from the head with the *highest* recovery (most over-provisioned,
    donor) to the head with the *lowest* recovery (receiver).  Stop when

    (i)  the transfer no longer yields benefit — the donor would become the
         new minimum (paper's dashed-line condition); or
    (ii) no donor can give without violating the ``floor``.

    ``init_budgets`` warm-starts the transfer loop from an existing
    allocation instead of the uniform split — the incremental replanning
    path (DESIGN.md §2.9): when the live profile has drifted only mildly
    from the one the previous epoch was planned on, the previous budgets
    are near-optimal and the loop converges in a handful of transfers
    instead of O(total/block).  The warm start is re-centered onto
    ``total`` first, so a replan can also change the global budget.
    """
    curves, grid = _as_curves(profile, layer)
    H = curves.shape[0]
    if init_budgets is not None:
        assert len(init_budgets) == H, (
            f"warm start has {len(init_budgets)} heads, curves {H}")
        budgets = _quantize(np.asarray(init_budgets, np.float64),
                            block, floor, seq_len)
        budgets = _rebalance_total(budgets, total, block, floor, seq_len,
                                   curves=curves, grid=grid)
    else:
        base = max(floor, int(round(total / H)))
        budgets = _quantize(np.full(H, base), block, floor, seq_len)
        # Re-center onto the global total as closely as quantization allows.
        budgets = _rebalance_total(budgets, total, block, floor, seq_len)

    rec = _recovery_tokens(curves, grid, seq_len, budgets)
    iters = 0
    while iters < max_iters:
        iters += 1
        recv = int(np.argmin(rec))
        # donor: highest recovery among heads that can still give a block
        can_give = budgets - block >= floor
        can_give[recv] = False
        if not can_give.any():
            break  # condition (ii): everyone at the floor
        donor_candidates = np.where(can_give)[0]
        donor = int(donor_candidates[np.argmax(rec[donor_candidates])])
        if budgets[recv] + block > seq_len:
            break  # receiver saturated: nothing to improve
        # tentative transfer
        new_donor_rec = np.interp(
            (budgets[donor] - block) / seq_len, grid, curves[donor])
        new_recv_rec = np.interp(
            (budgets[recv] + block) / seq_len, grid, curves[recv])
        old_min = rec[recv]
        others = np.delete(rec, [donor, recv])
        others_min = float(others.min()) if others.size else np.inf
        new_min = min(float(new_donor_rec), float(new_recv_rec), others_min)
        if new_min <= old_min + 1e-12:
            break  # condition (i): donor becomes the new minimum — no benefit
        budgets[donor] -= block
        budgets[recv] += block
        rec[donor] = new_donor_rec
        rec[recv] = new_recv_rec
    return AllocationResult(budgets, rec, iters, int(budgets.sum()))


# ---------------------------------------------------------------------------
# Beyond paper: exact water-filling max-min solver
# ---------------------------------------------------------------------------

def waterfill_allocation(
    profile, *, layer: int | None = None, total: int, seq_len: int,
    block: int = 128, floor: int = 128, tol: float = 1e-6,
) -> AllocationResult:
    """Exact continuous max-min allocation via bisection on the water level.

    At the optimum every head is either (a) at the floor, (b) at the ceiling
    ``seq_len``, or (c) at the budget whose recovery equals the common level
    ``r*``.  Monotone curves make ``spend(r*)`` monotone, so bisect on r*,
    then block-quantize and spend any quantization slack on the lowest-
    recovery heads.  Serves as oracle for :func:`maxmin_allocation` (the
    greedy must come within one block-quantum of this optimum).
    """
    curves, grid = _as_curves(profile, layer)
    H = curves.shape[0]

    def budget_for(h: int, r: float) -> float:
        # smallest fraction with recovery >= r (inverse interp), in tokens
        c = curves[h]
        if r <= c[0]:
            return float(floor)
        if r >= c[-1]:
            return float(seq_len)
        f = np.interp(r, c, grid)
        return float(np.clip(f * seq_len, floor, seq_len))

    def spend(r: float) -> float:
        return sum(budget_for(h, r) for h in range(H))

    lo, hi = 0.0, 1.0
    it = 0
    while hi - lo > tol and it < 200:
        it += 1
        mid = 0.5 * (lo + hi)
        if spend(mid) <= total:
            lo = mid
        else:
            hi = mid
    budgets = np.array([budget_for(h, lo) for h in range(H)])
    budgets = _quantize(budgets, block, floor, seq_len)
    budgets = _rebalance_total(budgets, total, block, floor, seq_len,
                               curves=curves, grid=grid)
    rec = _recovery_tokens(curves, grid, seq_len, budgets)
    return AllocationResult(budgets, rec, it, int(budgets.sum()))


def _rebalance_total(
    budgets: np.ndarray, total: int, block: int, floor: int, seq_len: int,
    curves: np.ndarray | None = None, grid: np.ndarray | None = None,
) -> np.ndarray:
    """Adjust block-quantized budgets to sum as close to ``total`` as possible.

    Surplus is taken from (or deficit given to) heads chosen greedily: when
    curves are provided, give to the lowest-recovery head / take from the
    highest-recovery head; otherwise round-robin.  Never violates floor/cap.
    """
    budgets = budgets.copy()
    H = len(budgets)
    max_steps = max(1000, 8 * H)  # slack after quantization is O(H) blocks

    def rec_of(b):
        if curves is None:
            return np.zeros(H)
        return _recovery_tokens(curves, grid, seq_len, b)

    guard = 0
    while budgets.sum() + block <= total and guard < max_steps:
        guard += 1
        r = rec_of(budgets)
        order = np.argsort(r) if curves is not None else np.arange(H)
        for h in order:
            if budgets[h] + block <= seq_len:
                budgets[h] += block
                break
        else:
            break
    while budgets.sum() - block >= total and guard < max_steps:
        guard += 1
        # Take from the head whose recovery AFTER the removal stays highest
        # (max-min-preserving) — NOT from the currently-highest head, whose
        # recovery may cliff once pushed to the floor (sparse heads).
        if curves is not None:
            can = budgets - block >= floor
            if not can.any():
                break
            cand = np.where(can)[0]
            after = np.array([
                np.interp((budgets[h] - block) / seq_len, grid, curves[h])
                for h in cand
            ])
            budgets[cand[np.argmax(after)]] -= block
        else:
            for h in range(H):
                if budgets[h] - block >= floor:
                    budgets[h] -= block
                    break
            else:
                break
    return budgets
