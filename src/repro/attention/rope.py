"""Rotary position embeddings (RoPE) — shared by all attention archs."""
from __future__ import annotations

import jax.numpy as jnp


def rope_tables(head_dim: int, max_len: int, theta: float = 10000.0,
                dtype=jnp.float32):
    """``(cos[max_len, head_dim/2], sin[...])`` tables."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    t = jnp.arange(max_len, dtype=jnp.float32)
    freqs = jnp.outer(t, inv)  # [max_len, head_dim/2]
    return jnp.cos(freqs).astype(dtype), jnp.sin(freqs).astype(dtype)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """Apply RoPE to ``x`` of shape ``[..., S, Dh]`` at ``positions [S]``.

    Split-halves convention (x = [x1, x2]; rotate pairs (x1[i], x2[i])) —
    matches Llama-family checkpoints.
    """
    dh = x.shape[-1]
    half = dh // 2
    inv = 1.0 / (theta ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    freqs = positions.astype(jnp.float32)[:, None] * inv[None, :]  # [S, half]
    cos = jnp.cos(freqs)
    sin = jnp.sin(freqs)
    x1, x2 = x[..., :half], x[..., half:]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2], axis=-1).astype(x.dtype)
